"""Observability lint (FED601–FED602).

The telemetry layer (``src/repro/obs/``, docs/OBSERVABILITY.md) is the
*only* sanctioned way library code reports what it is doing:

* FED601 — ``print(...)`` or the stdlib ``logging`` module inside the
  library core.  Both bypass the ring-buffer recorders (events are lost
  to exporters), serialize hot paths on interpreter-global locks, and —
  for the worker processes — interleave with the parent's stdout.
  Record a ``Telemetry`` event/metric instead; CLI entry points
  (``src/repro/launch/``) may print.
* FED602 — direct monotonic-clock reads (``time.monotonic``,
  ``time.perf_counter``, ...) anywhere but ``repro.obs.clock``.  Every
  timestamp must come from the one clock shim so cross-process dumps
  re-anchor onto a single timeline (and so tests can interpose the
  clock in one place).  ``time.sleep`` is not a read and stays fine.

Deliberate exceptions carry ``# fedlint: obs-ok(reason)``.
"""

from __future__ import annotations

import ast

from scripts.fedlint.core import Finding, Rule, SourceFile

CORE_PREFIX = "src/repro/core/"
OBS_PREFIX = "src/repro/obs/"

#: the one module allowed to touch ``time`` clocks directly
SANCTIONED_CLOCK = "src/repro/obs/clock.py"

MONO_READS = frozenset({
    "monotonic", "monotonic_ns", "perf_counter", "perf_counter_ns",
})

HATCH = "obs"


class ObservabilityRule(Rule):
    name = "observability"
    id_docs = {
        "FED601": "print()/logging in library core; record telemetry "
                  "events instead",
        "FED602": "monotonic clock read outside repro.obs.clock",
    }

    def applies(self, rel: str) -> bool:
        return rel.startswith((CORE_PREFIX, OBS_PREFIX))

    def check(self, src: SourceFile) -> list[Finding]:
        out: list[Finding] = []

        def flag(line: int, rule_id: str, msg: str) -> None:
            if not src.hatched(line, HATCH):
                out.append(Finding(src.rel, line, rule_id, msg))

        for node in ast.walk(src.tree):
            # FED601: print(...) calls
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "print"):
                flag(node.lineno, "FED601",
                     "`print()` in library core bypasses the telemetry "
                     "recorders and interleaves with worker stdout; "
                     "record a `Telemetry` event or metric instead")
            # FED601: stdlib logging (import or attribute use)
            elif (isinstance(node, (ast.Import, ast.ImportFrom))
                    and any((alias.name == "logging"
                             or alias.name.startswith("logging."))
                            for alias in node.names)
                    and (not isinstance(node, ast.ImportFrom)
                         or node.module in (None, "logging"))):
                flag(node.lineno, "FED601",
                     "stdlib `logging` in library core serializes hot "
                     "paths on a global lock; record a `Telemetry` "
                     "event or metric instead")
            # FED602: monotonic reads outside the clock shim
            elif (src.rel != SANCTIONED_CLOCK
                    and isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "time"
                    and node.attr in MONO_READS):
                flag(node.lineno, "FED602",
                     f"`time.{node.attr}` read outside repro.obs.clock; "
                     f"go through `repro.obs.clock.{node.attr}` so every "
                     f"timestamp shares one re-anchorable clock")
            elif (src.rel != SANCTIONED_CLOCK
                    and isinstance(node, ast.ImportFrom)
                    and node.module == "time"
                    and any(alias.name in MONO_READS
                            for alias in node.names)):
                flag(node.lineno, "FED602",
                     "importing monotonic clocks from `time` outside "
                     "repro.obs.clock; import them from "
                     "`repro.obs.clock` instead")
        return sorted(set(out))
