"""Analyzer plumbing: findings, parsed sources, rule protocol, runner.

Rules come in two shapes:

* per-file rules implement ``applies(rel)`` + ``check(src)`` and see one
  :class:`SourceFile` at a time;
* repo rules implement ``finalize(ctx)`` and read whatever files they
  need through the :class:`Context` (which supports text overrides so
  tests can patch a constant without touching the tree).

Escape hatches are line comments of the form::

    x = self.total  # fedlint: unlocked-ok(single torn read tolerated: stats)

The reason string in parentheses is mandatory; a hatch without one is
itself a finding (FED103) and does not suppress anything.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parents[2]

#: path fragments never scanned by the CLI walker (golden-bad fixtures
#: must be reachable by tests, not by ``fedlint src/ tests/``).
SKIP_PARTS = frozenset({"__pycache__", "fixtures", ".git"})

HATCH_RE = re.compile(r"#\s*fedlint:\s*([a-z][a-z-]*)-ok\s*(?:\(([^)#]*)\))?")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str  # repo-relative posix path
    line: int
    rule: str  # stable ID, e.g. "FED101"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


class SourceFile:
    """A parsed python file plus its per-line escape hatches."""

    def __init__(self, path: pathlib.Path, rel: str | None = None,
                 text: str | None = None):
        self.path = path
        if rel is None:
            try:
                rel = path.resolve().relative_to(REPO).as_posix()
            except ValueError:
                rel = path.as_posix()
        self.rel = rel
        self.text = path.read_text() if text is None else text
        self.tree = ast.parse(self.text, filename=str(path))
        # line -> [(tag, reason or None)]
        self.hatches: dict[int, list[tuple[str, str | None]]] = {}
        for lineno, line in enumerate(self.text.splitlines(), start=1):
            for m in HATCH_RE.finditer(line):
                reason = m.group(2)
                reason = reason.strip() if reason is not None else None
                self.hatches.setdefault(lineno, []).append(
                    (m.group(1), reason or None))

    def hatched(self, line: int, tag: str) -> bool:
        """True when a *valid* hatch for ``tag`` covers ``line``.

        A hatch covers its own line and the line directly below it (so a
        standalone comment can precede a long statement).
        """
        for cand in (line, line - 1):
            for t, reason in self.hatches.get(cand, ()):
                if t == tag and reason:
                    return True
        return False

    def bad_hatches(self) -> list[tuple[int, str]]:
        """(line, tag) for every hatch missing its reason string."""
        return [
            (lineno, tag)
            for lineno, entries in sorted(self.hatches.items())
            for tag, reason in entries
            if not reason
        ]


class Context:
    """Repo handle for repo-level rules; supports per-file text overrides."""

    def __init__(self, root: pathlib.Path = REPO,
                 overrides: dict[str, str] | None = None,
                 scanned: tuple[str, ...] = ()):
        self.root = pathlib.Path(root)
        self.overrides = dict(overrides or {})
        self.scanned = tuple(scanned)
        self._cache: dict[str, SourceFile] = {}

    def read(self, rel: str) -> str:
        if rel in self.overrides:
            return self.overrides[rel]
        return (self.root / rel).read_text()

    def source(self, rel: str) -> SourceFile:
        if rel not in self._cache:
            self._cache[rel] = SourceFile(
                self.root / rel, rel=rel, text=self.read(rel))
        return self._cache[rel]

    def exists(self, rel: str) -> bool:
        return rel in self.overrides or (self.root / rel).exists()

    def covers(self, rel_prefix: str) -> bool:
        """Did the requested scan include anything under ``rel_prefix``?"""
        if not self.scanned:
            return True
        return any(
            s == rel_prefix or s.startswith(rel_prefix + "/")
            or rel_prefix.startswith(s + "/") or s == ""
            for s in self.scanned
        )


class Rule:
    """Base rule.  ``id_docs`` maps every finding ID the rule can emit to
    a one-line description (surfaced by ``--list-rules`` and cross-checked
    against docs/INVARIANTS.md by scripts/check_docs.py)."""

    id_docs: dict[str, str] = {}
    name = "rule"

    def applies(self, rel: str) -> bool:
        return False

    def check(self, src: SourceFile) -> list[Finding]:
        return []

    def finalize(self, ctx: Context) -> list[Finding]:
        return []


def walk(paths: list[str | pathlib.Path],
         root: pathlib.Path = REPO) -> list[pathlib.Path]:
    """Expand CLI path arguments into a sorted list of .py files."""
    out: set[pathlib.Path] = set()
    for raw in paths:
        p = pathlib.Path(raw)
        if not p.is_absolute():
            p = root / p
        if p.is_file() and p.suffix == ".py":
            out.add(p.resolve())
            continue
        for sub in p.rglob("*.py"):
            if SKIP_PARTS.isdisjoint(sub.parts):
                out.add(sub.resolve())
    return sorted(out)


def relpath(p: pathlib.Path, root: pathlib.Path = REPO) -> str:
    try:
        return p.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return p.as_posix()


def run(paths: list[str | pathlib.Path], rules=None,
        root: pathlib.Path = REPO,
        graph_out: pathlib.Path | None = None) -> list[Finding]:
    """Run every rule over ``paths`` and return sorted findings."""
    if rules is None:
        from scripts.fedlint.rules import REGISTRY
        rules = [cls() for cls in REGISTRY.values()]
    files = walk(paths, root=root)
    scanned = tuple(relpath(f, root) for f in files)
    ctx = Context(root=root, scanned=scanned)
    if graph_out is not None:
        ctx.graph_out = pathlib.Path(graph_out)  # read by the lock-order rule
    findings: list[Finding] = []
    for f in files:
        rel = relpath(f, root)
        src = None
        for rule in rules:
            if not rule.applies(rel):
                continue
            if src is None:
                src = ctx.source(rel)
            findings.extend(rule.check(src))
    for rule in rules:
        findings.extend(rule.finalize(ctx))
    return sorted(set(findings))
