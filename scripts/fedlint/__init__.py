"""fedlint — repo-specific static analysis for the FedCCL reproduction.

The four server topologies (single-lock, thread-sharded, process-sharded,
multi-host TCP) stay equivalent only while a handful of conventions hold:
shared mutable state is touched under its lock, kernels ship signature-
identical ``ops``/``ref`` twins, the wire constants match
``docs/WIRE_PROTOCOL.md``, and nothing in the deterministic core consults
an unseeded RNG or the wall clock.  ``fedlint`` checks those conventions
at lint time, before the (much slower) equivalence matrix runs.

Usage::

    python -m scripts.fedlint src/ tests/ [--graph-out lock_order.dot]

Rule IDs are stable and documented in ``docs/INVARIANTS.md``.
"""

from scripts.fedlint.core import Context, Finding, SourceFile, run
from scripts.fedlint.rules import REGISTRY, rule_ids

__all__ = [
    "Context",
    "Finding",
    "REGISTRY",
    "SourceFile",
    "rule_ids",
    "run",
]
