"""CLI: ``python -m scripts.fedlint src/ tests/ [--graph-out PATH]``.

Exit status 0 means every rule passed; 1 means findings (printed one per
line as ``path:line: RULEID message``); 2 means usage error.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from scripts.fedlint.core import REPO, run, walk
from scripts.fedlint.rules import rule_ids


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m scripts.fedlint",
        description="FedCCL repo-specific static analysis "
                    "(see docs/INVARIANTS.md)")
    ap.add_argument("paths", nargs="*", default=["src", "tests"],
                    help="files or directories to scan "
                         "(default: src tests)")
    ap.add_argument("--graph-out", type=pathlib.Path, default=None,
                    help="write the static lock-order graph as DOT")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every finding ID and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, doc in rule_ids().items():
            print(f"{rid}  {doc}")
        return 0

    paths = args.paths or ["src", "tests"]
    findings = run(paths, root=REPO, graph_out=args.graph_out)
    for f in findings:
        print(f.render())
    n_files = len(walk(paths, root=REPO))
    if findings:
        print(f"fedlint: {len(findings)} finding(s) in {n_files} files",
              file=sys.stderr)
        return 1
    print(f"fedlint OK — {n_files} files clean", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
