"""Docs drift gate — keeps ``docs/`` and ``README.md`` truthful.

Three checks, run by the CI ``docs`` job (and ``tests/test_docs.py``):

  1. **Config coverage** — every ``FedCCLConfig`` dataclass field must
     appear (as a backticked token) in ``docs/OPERATIONS.md``.  Add a
     knob, document it, or this gate fails.
  2. **Reference liveness** — every repo path (``src/...py``,
     ``tests/...py``, ...) and every ``repro.*`` dotted symbol mentioned
     in ``docs/*.md`` or ``README.md`` must exist/import.  Renames that
     orphan the docs fail here.
  3. **Runnable snippets** — every ```` ```python ```` block in
     ``README.md`` and ``docs/*.md`` is executed against a reduced smoke
     namespace (tiny params, trivial ``train_fn``, three
     ``client_specs``), so the documented API calls are guaranteed to
     run.  Shell blocks are checked for dead script paths.
  4. **fedlint catalog coverage** — the finding IDs registered by
     ``scripts/fedlint`` and the IDs documented in
     ``docs/INVARIANTS.md`` must match exactly, in both directions: a
     new rule without a catalog section fails, and so does a stale ID
     left behind after a rule is removed.

Usage:
  PYTHONPATH=src python scripts/check_docs.py            # gate
  PYTHONPATH=src python scripts/check_docs.py --list     # show references
"""

from __future__ import annotations

import argparse
import importlib
import pathlib
import re
import sys
import traceback

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

DOC_FILES = sorted(pathlib.Path(REPO, "docs").glob("*.md")) + \
    [REPO / "README.md"]

# path-like references: a known top-level dir followed by a concrete path
_PATH_RE = re.compile(
    r"\b(?:src|tests|docs|benchmarks|scripts|examples)/[\w./-]*[\w]")
# dotted code references rooted at the package
_SYMBOL_RE = re.compile(r"\brepro(?:\.\w+)+")
_PY_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.S)
_SH_BLOCK_RE = re.compile(r"```(?:bash|sh|shell)\n(.*?)```", re.S)


# ------------------------------------------------------------- check 1

def undocumented_config_fields(ops_text: str | None = None) -> list[str]:
    """FedCCLConfig fields missing from docs/OPERATIONS.md."""
    import dataclasses

    from repro.core.fedccl import FedCCLConfig

    if ops_text is None:
        ops_text = (REPO / "docs" / "OPERATIONS.md").read_text()
    return [f.name for f in dataclasses.fields(FedCCLConfig)
            if f"`{f.name}`" not in ops_text]


# ------------------------------------------------------------- check 2

def collect_references(text: str) -> tuple[set[str], set[str]]:
    """(paths, symbols) referenced by one markdown document."""
    paths = {m.group(0).rstrip("/.") for m in _PATH_RE.finditer(text)}
    symbols = {m.group(0).rstrip(".") for m in _SYMBOL_RE.finditer(text)}
    return paths, symbols


def dead_references(files=None) -> list[str]:
    """Referenced paths that don't exist / symbols that don't resolve."""
    problems = []
    for doc in (files if files is not None else DOC_FILES):
        paths, symbols = collect_references(doc.read_text())
        for p in sorted(paths):
            if not (REPO / p).exists():
                problems.append(f"{doc.name}: dead path reference `{p}`")
        for s in sorted(symbols):
            if not _resolves(s):
                problems.append(f"{doc.name}: dead symbol reference `{s}`")
    return problems


def _resolves(dotted: str) -> bool:
    """Import the longest module prefix of ``dotted``, then walk attrs."""
    parts = dotted.split(".")
    for cut in range(len(parts), 0, -1):
        mod_name = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(mod_name)
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return False
        return True
    return False


# ------------------------------------------------------------- check 3

def _smoke_namespace() -> dict:
    """The reduced smoke config the doc snippets exec against: a tiny
    param tree, a trivial train_fn, and three clustered orgs."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.protocol import ClientSpec

    def train_fn(params, dataset, rng, anchor):
        return {"w": params["w"] + 0.01}, 16, 1

    client_specs = [
        ClientSpec(f"org-{i}",
                   {"loc": np.array([48.0 + 0.1 * i, 16.0 + 0.1 * i]),
                    "ori": np.array([30.0 + i])}, None)
        for i in range(3)]
    return {"init_params": {"w": jnp.zeros(8, jnp.float32)},
            "train_fn": train_fn, "client_specs": client_specs}


def failing_code_blocks(files=None) -> list[str]:
    """Execute every ```python block; flag dead script paths in shell
    blocks.  Returns human-readable failure strings."""
    problems = []
    for doc in (files if files is not None else DOC_FILES):
        text = doc.read_text()
        for i, block in enumerate(_PY_BLOCK_RE.findall(text)):
            ns = _smoke_namespace()
            try:
                exec(compile(block, f"{doc.name}#python-block-{i}", "exec"),
                     ns)
            except BaseException:
                problems.append(
                    f"{doc.name}: python block {i} failed:\n"
                    + traceback.format_exc(limit=3))
        for block in _SH_BLOCK_RE.findall(text):
            for script in re.findall(
                    r"\b(?:scripts|examples|benchmarks)/[\w/-]+\.py", block):
                if not (REPO / script).exists():
                    problems.append(
                        f"{doc.name}: shell block references missing "
                        f"script `{script}`")
    return problems


# ------------------------------------------------------------- check 4

_FED_ID_RE = re.compile(r"\bFED\d{3}\b")


def fedlint_catalog_drift() -> list[str]:
    """Bidirectional diff between the fedlint rule registry and the
    ``docs/INVARIANTS.md`` catalog."""
    if str(REPO) not in sys.path:
        sys.path.insert(0, str(REPO))
    from scripts.fedlint.rules import rule_ids

    registered = set(rule_ids())
    documented = set(_FED_ID_RE.findall(
        (REPO / "docs" / "INVARIANTS.md").read_text()))
    problems = []
    for rid in sorted(registered - documented):
        problems.append(f"INVARIANTS.md: registered fedlint rule `{rid}` "
                        f"has no catalog entry")
    for rid in sorted(documented - registered):
        problems.append(f"INVARIANTS.md: documents `{rid}` but no fedlint "
                        f"rule registers that ID")
    return problems


# ----------------------------------------------------------------- main

def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print collected references and exit")
    ap.add_argument("--skip-exec", action="store_true",
                    help="skip executing the python doc blocks")
    args = ap.parse_args()

    if args.list:
        for doc in DOC_FILES:
            paths, symbols = collect_references(doc.read_text())
            print(f"== {doc.name}: {len(paths)} paths, "
                  f"{len(symbols)} symbols")
            for p in sorted(paths):
                print("  path  ", p)
            for s in sorted(symbols):
                print("  symbol", s)
        return 0

    failures = []
    missing = undocumented_config_fields()
    failures += [f"OPERATIONS.md: undocumented FedCCLConfig field "
                 f"`{name}`" for name in missing]
    failures += dead_references()
    failures += fedlint_catalog_drift()
    if not args.skip_exec:
        failures += failing_code_blocks()

    if failures:
        print(f"[check-docs] FAIL — {len(failures)} problem(s):")
        for f in failures:
            print("  -", f)
        return 1
    n_blocks = sum(len(_PY_BLOCK_RE.findall(d.read_text()))
                   for d in DOC_FILES)
    print(f"[check-docs] OK — {len(DOC_FILES)} docs, every FedCCLConfig "
          f"field documented, all references live, fedlint catalog in "
          f"sync, {n_blocks} python block(s) executed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
