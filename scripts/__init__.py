# Marks scripts/ as a package so `python -m scripts.fedlint` works from
# the repo root.  The standalone entry points (check_docs.py, bench_gate.py,
# hillclimb.py) are unaffected — they are run as plain files.
