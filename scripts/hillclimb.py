import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-hillclimb runner: one named experiment variant per invocation
(fresh process so XLA device config and env knobs are clean).

  PYTHONPATH=src python scripts/hillclimb.py <variant> [--out artifacts/perf.jsonl]

Variants encode hypothesis -> change on the three chosen (arch x shape)
pairs; results append to the JSONL consumed by EXPERIMENTS.md §Perf.
"""

import argparse
import json

VARIANTS = {}


def variant(name):
    def deco(fn):
        VARIANTS[name] = fn
        return fn
    return deco


# =========================================================================
# A. internvl2-76b x train_4k — compute-dominant, 261 GB temp memory
# =========================================================================

@variant("A0_baseline")
def a0():
    from repro.launch.dryrun import run_one
    return run_one("internvl2-76b", "train_4k", remat="full", verbose=False)


@variant("A1_flash_train")
def a1():
    """H: dropping FLASH_THRESHOLD to 1024 removes the materialized
    (b,h,4k,4k) f32 score tensors -> temp memory way down, terms ~equal."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    from repro.launch.dryrun import run_one
    return run_one("internvl2-76b", "train_4k", remat="full", verbose=False)


@variant("A2_flash_dots_saveable")
def a2():
    """H: with flash keeping activations small, relaxing remat full ->
    dots_saveable cuts the recompute pass: compute mult 4x -> 3x
    (analytic compute term -25%) at an acceptable temp-memory cost."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    from repro.launch.dryrun import run_one
    return run_one("internvl2-76b", "train_4k", remat="dots_saveable",
                   verbose=False)


@variant("A3_flash_dots_bf16_moments")
def a3():
    """H: bf16 Adam moments halve optimizer HBM traffic and shard bytes
    (memory term down; compute unchanged)."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    from repro.launch.dryrun import run_one
    return run_one("internvl2-76b", "train_4k", remat="dots_saveable",
                   moment_dtype="bfloat16", verbose=False)


@variant("A4_sequence_parallel")
def a4():
    """H(from A1/A2 refutations): the 261 GB temp is per-layer scan carries
    + CE chain, both (b, s, ...) activations — sharding the activation
    `seq` axis over `model` (Megatron sequence parallelism) divides those
    temps by 16 at the cost of per-layer seq all-gathers before attention.
    Predict: temp ~261/16 + params-ish ~= 20-30 GB; collective term up."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    from repro.launch.dryrun import run_one
    return run_one("internvl2-76b", "train_4k", remat="full",
                   extra_rules={"seq": "model"}, verbose=False)


@variant("A5_seqpar_dots")
def a5():
    """H: with sequence parallelism paying the memory bill, retry
    dots_saveable for the 4x->3x compute win (A2's 913 GB becomes ~57 GB
    when the saved dots are seq-sharded)."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    from repro.launch.dryrun import run_one
    return run_one("internvl2-76b", "train_4k", remat="dots_saveable",
                   extra_rules={"seq": "model"}, verbose=False)


@variant("A6_seqpar_microbatch8")
def a6():
    """H: gradient accumulation over 8 microbatches divides the remaining
    (b, ...) activation temps by 8 on top of A4: predict ~71/8 + params
    ~= 10-15 GB/device — the first variant that actually fits v5e HBM.
    Compute/memory/collective terms unchanged (same total work)."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    from repro.launch.dryrun import run_one
    return run_one("internvl2-76b", "train_4k", remat="full",
                   extra_rules={"seq": "model"}, n_microbatches=8,
                   verbose=False)


# =========================================================================
# B. deepseek-v3-671b x decode_32k — most collective-bound (26% useful)
# =========================================================================

@variant("B0_baseline_absorbed")
def b0():
    from repro.launch.dryrun import run_one
    return run_one("deepseek-v3-671b", "decode_32k", verbose=False)


@variant("B0n_paper_naive_mla")
def b0n():
    """Paper-faithful naive MLA decode (re-expand K/V from the latent every
    step) — recorded as the reproduction baseline; absorbed path (B0) is
    the beyond-paper optimization."""
    from repro.launch.dryrun import run_one
    return run_one("deepseek-v3-671b", "decode_32k", mla_absorb=False,
                   verbose=False)


@variant("B1_no_fsdp_gather_at_decode")
def b1():
    """H: at decode there is no optimizer, so FSDP (embed->data) param
    sharding only adds a 617 MB/step all-gather over `data`; resharding
    params to model-only (embed->None) kills it.  Risk: params/device grow
    16x for non-expert weights — check memory_analysis."""
    from repro.launch.dryrun import run_one
    return run_one("deepseek-v3-671b", "decode_32k",
                   extra_rules={"embed": None}, verbose=False)


@variant("B2_experts_over_full_mesh")
def b2():
    """H: expert weights dominate dsv3 params; sharding the expert axis
    over BOTH mesh axes (256 experts / 256 chips) keeps per-device memory
    flat while removing the expert-tensor share of the data all-gather;
    token dispatch becomes a small all-to-all."""
    from repro.launch.dryrun import run_one
    return run_one("deepseek-v3-671b", "decode_32k",
                   extra_rules={"embed": None, "expert": ("data", "model")},
                   verbose=False)


# =========================================================================
# C. deepseek-moe-16b x train_4k — representative of the paper's technique
#    (federated fine-tune target); compute-dominant, 9.9 GB all-reduce
# =========================================================================

@variant("C0_baseline")
def c0():
    from repro.launch.dryrun import run_one
    return run_one("deepseek-moe-16b", "train_4k", remat="full", verbose=False)


@variant("C1_dots_saveable")
def c1():
    """H: remat full->dots_saveable drops the extra fwd recompute:
    analytic compute mult 4->3 (-25% on the dominant term)."""
    from repro.launch.dryrun import run_one
    return run_one("deepseek-moe-16b", "train_4k", remat="dots_saveable",
                   verbose=False)


@variant("C2_flash_and_dots")
def c2():
    """H: flash attention at 4k additionally cuts temp memory (score
    tensors) with no compute-term change — memory headroom banked."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    from repro.launch.dryrun import run_one
    return run_one("deepseek-moe-16b", "train_4k", remat="dots_saveable",
                   verbose=False)


@variant("C3_capacity_1_0")
def c3():
    """H: MoE capacity factor 1.25 -> 1.0 cuts routed-expert compute by
    20% (top-6 of 64 is already balanced on synthetic data; drops are
    acceptable in fine-tuning) — compute term down ~proportionally."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    import repro.configs as C
    from repro.launch import dryrun as dr
    import dataclasses

    real_get = C.get_config

    def patched(arch):
        cfg = real_get(arch)
        if cfg.moe:
            cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                      capacity_factor=1.0))
        return cfg

    dr.get_config = patched
    return dr.run_one("deepseek-moe-16b", "train_4k", remat="dots_saveable",
                      verbose=False)


@variant("C4_expert_over_full_mesh")
def c4():
    """H: 64 experts over (data x model)=256 won't divide (64 < 256 uses
    the divisibility guard -> falls back) — try experts over data (16-way,
    4 experts/device) instead of model: moves expert all-gathers off the
    model axis, trades with grad all-reduce locality."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    from repro.launch.dryrun import run_one
    return run_one("deepseek-moe-16b", "train_4k", remat="dots_saveable",
                   extra_rules={"expert": "data"}, verbose=False)


# =========================================================================
# D. recurrentgemma-9b x prefill_32k — bonus pair: collective-dominant at
#    98% useful flops (the collectives are pure overhead, not work)
# =========================================================================

@variant("D0_baseline")
def d0():
    from repro.launch.dryrun import run_one
    return run_one("recurrentgemma-9b", "prefill_32k", verbose=False)


@variant("D1_seqpar_prefill")
def d1():
    """H: the 19 GB/step of all-reduce comes from activation resharding
    between recurrent blocks (lru axis on `model`) and local-attn blocks
    (heads on `model`): the residual stream bounces between layouts every
    pattern group.  Sharding the residual's seq axis over `model` gives
    both block types one stable layout; predict most all-reduce replaced
    by cheaper gathers."""
    from repro.launch.dryrun import run_one
    return run_one("recurrentgemma-9b", "prefill_32k",
                   extra_rules={"seq": "model"}, verbose=False)


@variant("D2_replicate_lru")
def d2():
    """H(alt): keep activations replicated on `model` for the recurrent
    branch by NOT sharding the lru width (lru->None): removes the
    per-block reshard at the cost of 16x more per-device lru compute —
    likely a net loss (compute term up), but measures the attribution."""
    from repro.launch.dryrun import run_one
    return run_one("recurrentgemma-9b", "prefill_32k",
                   extra_rules={"lru": None}, verbose=False)


@variant("C5_best_combo")
def c5():
    """H: combine the confirmed wins under a memory-feasible policy:
    remat=full (C1's dots_saveable exploded temps 45->260 GB), capacity 1.0
    (-11% compute, C3), sequence parallelism + microbatch 4 (A4/A6 lesson)
    to push temp under ~12 GB.  Predict: compute ~0.47 s (full-remat mult
    4/3 of C3's 0.356), temp ~45/(16*4)+overheads ~= 5-10 GB."""
    os.environ["REPRO_FLASH_THRESHOLD"] = "1024"
    import dataclasses
    import repro.configs as C
    from repro.launch import dryrun as dr

    real_get = C.get_config

    def patched(arch):
        cfg = real_get(arch)
        if cfg.moe:
            cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                      capacity_factor=1.0))
        return cfg

    dr.get_config = patched
    return dr.run_one("deepseek-moe-16b", "train_4k", remat="full",
                      extra_rules={"seq": "model"}, n_microbatches=4,
                      verbose=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("variant", choices=sorted(VARIANTS))
    ap.add_argument("--out", default="artifacts/perf.jsonl")
    args = ap.parse_args()
    rec = VARIANTS[args.variant]()
    rec["variant"] = args.variant
    with open(args.out, "a") as f:
        f.write(json.dumps(rec, default=str) + "\n")
    t = rec.get("roofline", {})
    print(json.dumps({
        "variant": args.variant, "status": rec.get("status"),
        "compute_s": t.get("compute_s"), "memory_s": t.get("memory_s"),
        "collective_s": t.get("collective_s"), "dominant": t.get("dominant"),
        "temp_GB": (rec.get("memory", {}).get("temp_bytes") or 0) / 1e9,
        "useful": rec.get("useful_flops_ratio"),
    }, indent=None))


if __name__ == "__main__":
    main()
