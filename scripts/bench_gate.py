"""CI bench-regression gate.

Runs the fast (``REPRO_BENCH_FAST=1``-sized) benchmarks N times (default
3), takes per-metric **medians** (noise tolerance on shared CI runners),
compares them against the committed baselines in ``benchmarks/baselines/``,
and fails on any throughput regression beyond ``--tolerance`` (default 25%).
A merged ``bench_trajectory.json`` is always written — the CI job uploads
it as an artifact so every PR carries its measured trajectory next to the
committed baseline.

Gated metrics are **machine-relative ratios measured within one run** (a
sharded store's speedup over the single-lock store, the process store's
throughput over the threaded store, the secure drain's overhead over the
plain drain): absolute submits/s depend on the runner's CPU and would fail
the gate whenever GitHub swaps hardware, while same-run ratios cancel the
machine out and still catch real regressions in the optimized paths.
Absolute throughputs ride along in the trajectory as informational rows
(``ok: null``).  Pallas *interpret* timings are excluded — they measure
the Python interpreter, not the server, and swing beyond any tolerance.

Usage:
  python scripts/bench_gate.py                 # gate against baselines
  python scripts/bench_gate.py --update-baselines   # regenerate baselines
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import statistics
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO))
sys.path.insert(0, str(REPO / "src"))
os.environ.setdefault("REPRO_BENCH_FAST", "1")


# ------------------------------------------------------------- extractors
# Each returns {metric_name: (value, higher_is_better | None)} from one
# report.  higher_is_better None = informational (recorded, never gated).

def _sharded_metrics(report: dict) -> dict:
    out = {}
    for store, speedup in report["speedup_vs_single_lock"].items():
        if store != "single_lock":      # identically 1.0
            out[f"sharded/{store}/speedup_vs_single_lock"] = (speedup, True)
    for r in report["rows"]:
        out[f"sharded/{r['store']}/submits_per_s"] = \
            (r["submits_per_s"], None)
    return out


def _multiproc_metrics(report: dict) -> dict:
    out = {f"multiproc/process_vs_threaded/{k}": (v, True)
           for k, v in report["process_vs_threaded"].items()}
    for r in report["rows"]:
        out[f"multiproc/{r['store']}/submits_per_s"] = \
            (r["submits_per_s"], None)
        out[f"multiproc/{r['store']}/fetches_per_s"] = \
            (r["fetches_per_s"], None)
        if "wire_rx_bytes" in r:
            out[f"multiproc/{r['store']}/wire_rx_bytes"] = \
                (r["wire_rx_bytes"], None)
    ms = report.get("mirror_sync")
    if ms:
        # deterministic replay: lazy (sync4) reply bytes over eager
        # (sync1) — lower is better; weights equality is asserted inside
        # the benchmark itself, so a semantics break fails the run
        out["multiproc/tcp_reply_bytes_sync4_vs_sync1"] = \
            (ms["reply_bytes_ratio"], False)
        out["multiproc/tcp_sync1_reply_bytes"] = \
            (ms["sync1"]["reply_bytes"], None)
        out["multiproc/tcp_sync4_reply_bytes"] = \
            (ms["sync4"]["reply_bytes"], None)
    fs = report.get("fetch_storm")
    if fs:
        # read tier (wire v3), same run / same fan-in so the machine
        # cancels out: conditional worker-served fetches/s over the
        # pre-v3 parent-served path (higher is better), and the
        # conditional path's rx bytes over unconditional full fetches
        # (lower is better).  Fallbacks/respawns fail the bench itself.
        out["multiproc/fetch_storm/worker_vs_parent_fetches"] = \
            (fs["worker_vs_parent_fetches"], True)
        out["multiproc/fetch_storm/conditional_bytes_ratio"] = \
            (fs["conditional_bytes_ratio"], False)
        for mode in ("parent", "worker_full", "worker_cond"):
            out[f"multiproc/fetch_storm/{mode}_fetches_per_s"] = \
                (fs[mode]["fetches_per_s"], None)
        out["multiproc/fetch_storm/not_modified_frac"] = \
            (fs["not_modified_frac"], None)
    rb = report.get("rebalance")
    if rb:
        # live migration under load (docs/ELASTICITY.md §6), same run so
        # the machine cancels out: post-migration submits/s over
        # pre-migration (1.0 = the hand-off left no throughput scar,
        # higher is better).  The fence pause is absolute wall time —
        # informational, like the raw throughputs.
        out["multiproc/rebalance/recovery_ratio"] = \
            (rb["recovery_ratio"], True)
        out["multiproc/rebalance/fence_pause_ms"] = \
            (rb["fence_pause_ms"], None)
        out["multiproc/rebalance/pre_submits_per_s"] = \
            (rb["pre_submits_per_s"], None)
        out["multiproc/rebalance/post_submits_per_s"] = \
            (rb["post_submits_per_s"], None)
    tl = report.get("telemetry")
    if tl:
        # off/on submits/s within one run (machine cancels out); 1.0 =
        # telemetry is free.  Gated TIGHT (see TIGHT_TOLERANCE): the
        # docs' "<= 5% submit-throughput cost" claim is enforced, and
        # regressing it means a hook landed on the hot path
        out["multiproc/telemetry_overhead"] = (tl["overhead_ratio"], False)
        out["multiproc/telemetry_off_submits_per_s"] = \
            (tl["off_submits_per_s"], None)
        out["multiproc/telemetry_on_submits_per_s"] = \
            (tl["on_submits_per_s"], None)
    return out


def _privacy_metrics(report: dict) -> dict:
    out = {}
    for row in report.get("privatize", []):
        out[f"privacy/privatize_{row['params']}/jit_us"] = \
            (row["jit_us"], None)
    sd = report.get("secure_drain", {})
    if "secure_drain_us" in sd and sd.get("plain_drain_us"):
        out["privacy/secure_vs_plain_drain"] = \
            (sd["secure_drain_us"] / sd["plain_drain_us"], False)
        out["privacy/secure_drain_us"] = (sd["secure_drain_us"], None)
        out["privacy/plain_drain_us"] = (sd["plain_drain_us"], None)
    return out


def _scenarios_metrics(report: dict) -> dict:
    # trace-driven scenario runs (benchmarks/scenarios.py).  Integrity
    # SLOs (zero lost updates, monotone rounds) are asserted inside the
    # benchmark, so only performance-shaped verdicts appear here: the
    # same-run sharded/single throughput ratio (machine cancels out),
    # the deterministic staleness tail, and the EWC retention ratio.
    out = {
        "scenarios/sharded_vs_single_submits":
            (report["sharded_vs_single_submits"], True),
        "scenarios/diurnal_churn/staleness_p95":
            (report["staleness_p95"], False),
        "scenarios/drift_ewc/retention_ratio":
            (report["drift"]["retention_ratio"], True),
        "scenarios/drift_ewc/kernel_calls":
            (report["drift"]["kernel_calls"], None),
    }
    for r in report["rows"]:
        out[f"scenarios/{r['name']}/{r['topology']}/submits_per_s"] = \
            (r["submits_per_s"], None)
    return out


BENCHES = [
    # (module name, artifact file name, extractor)
    ("sharded_store", "BENCH_sharded.json", _sharded_metrics),
    ("multiproc_store", "BENCH_multiproc.json", _multiproc_metrics),
    ("privacy_overhead", "BENCH_privacy.json", _privacy_metrics),
    ("scenarios", "BENCH_scenarios.json", _scenarios_metrics),
]

# metrics whose run-to-run spread exceeds the default tolerance even as a
# median (the serving-mix ratio depends on OS scheduling of 10+ threads and
# K processes): gate them at 2x the tolerance — still catches the
# catastrophic regressions this pipeline exists for (e.g. a cold-compile
# reintroduction drops the ratio ~4x) without flaking on scheduler noise
WIDE_TOLERANCE_PREFIXES = ("multiproc/process_vs_threaded/",
                           "multiproc/fetch_storm/",
                           "multiproc/rebalance/",
                           "scenarios/sharded_vs_single_submits",
                           "scenarios/drift_ewc/retention_ratio")

# metrics that carry a documented *bound* rather than a throughput: the
# telemetry off/on ratio is near 1.0 by construction and its baseline is
# pinned there, so the default 25% would let a 25% telemetry tax through —
# gate it at the docs' promised 5% instead, overriding --tolerance
TIGHT_TOLERANCE = {"multiproc/telemetry_overhead": 0.05}


def _tolerance_for(metric: str, base_tol: float) -> float:
    if metric in TIGHT_TOLERANCE:
        return TIGHT_TOLERANCE[metric]
    if metric.startswith(WIDE_TOLERANCE_PREFIXES):
        return 2.0 * base_tol
    return base_tol


def run_benches(names, runs: int):
    """Run each benchmark ``runs`` times; returns (per-metric medians,
    last full report per bench)."""
    import importlib

    samples: dict[str, list] = {}
    direction: dict[str, bool] = {}
    reports: dict[str, dict] = {}
    for mod_name, artifact, extract in BENCHES:
        if names and mod_name not in names:
            continue
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        for i in range(runs):
            with tempfile.TemporaryDirectory() as td:
                report = mod.run(fast=True,
                                 out_path=os.path.join(td, artifact))
            reports[mod_name] = report
            for metric, (value, hib) in extract(report).items():
                samples.setdefault(metric, []).append(float(value))
                direction[metric] = hib
            print(f"[bench-gate] {mod_name} run {i + 1}/{runs} done",
                  flush=True)
    medians = {m: statistics.median(vs) for m, vs in samples.items()}
    return medians, direction, samples, reports


def load_baselines(baseline_dir: pathlib.Path) -> dict:
    metrics = {}
    for _, artifact, _ in BENCHES:
        path = baseline_dir / artifact
        if path.exists():
            blob = json.loads(path.read_text())
            metrics.update(blob.get("metrics", {}))
    return metrics


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--runs", type=int, default=3,
                    help="runs per benchmark; the gate compares medians")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative regression allowed before failing")
    ap.add_argument("--baseline-dir", default=str(REPO / "benchmarks" /
                                                  "baselines"))
    ap.add_argument("--out", default="bench_trajectory.json")
    ap.add_argument("--bench", action="append", default=None,
                    help="limit to one benchmark module (repeatable)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="write the measured medians as the new baselines "
                         "instead of gating")
    args = ap.parse_args()

    medians, direction, samples, reports = run_benches(args.bench, args.runs)
    baseline_dir = pathlib.Path(args.baseline_dir)

    if args.update_baselines:
        baseline_dir.mkdir(parents=True, exist_ok=True)
        for mod_name, artifact, extract in BENCHES:
            if args.bench and mod_name not in args.bench:
                continue
            # bound metrics gate against their documented ideal (1.0),
            # not whatever this machine happened to measure
            metrics = {m: (1.0 if m in TIGHT_TOLERANCE else medians[m])
                       for m in extract(reports[mod_name])}
            blob = {"source": f"median of {args.runs} REPRO_BENCH_FAST=1 "
                              f"runs (scripts/bench_gate.py)",
                    "metrics": metrics}
            (baseline_dir / artifact).write_text(json.dumps(blob, indent=2)
                                                 + "\n")
            print(f"[bench-gate] wrote {baseline_dir / artifact}")
        return 0

    baselines = load_baselines(baseline_dir)
    results, failures = {}, []
    for metric, median in sorted(medians.items()):
        base = baselines.get(metric)
        hib = direction[metric]
        entry = {"median": median, "samples": samples[metric],
                 "baseline": base, "higher_is_better": hib}
        if hib is not None and base is not None and base > 0:
            tol = _tolerance_for(metric, args.tolerance)
            ratio = median / base
            entry["ratio_vs_baseline"] = ratio
            entry["tolerance"] = tol
            ok = (ratio >= 1.0 - tol if hib else ratio <= 1.0 + tol)
            entry["ok"] = ok
            if not ok:
                failures.append(
                    f"{metric}: median {median:.1f} vs baseline {base:.1f} "
                    f"(ratio {ratio:.2f}, tol {tol:.0%}, "
                    f"{'higher' if hib else 'lower'} is better)")
        else:
            entry["ok"] = None        # no baseline: informational only
        results[metric] = entry

    trajectory = {
        "runs_per_bench": args.runs,
        "tolerance": args.tolerance,
        "results": results,
        "reports": reports,
        "failures": failures,
    }
    pathlib.Path(args.out).write_text(json.dumps(trajectory, indent=2) + "\n")
    print(f"[bench-gate] trajectory -> {args.out} "
          f"({len(results)} metrics, {len(failures)} regressions)")
    if failures:
        print("[bench-gate] FAIL — throughput regressions beyond "
              f"{args.tolerance:.0%}:")
        for f in failures:
            print("  ", f)
        return 1
    print("[bench-gate] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
