"""Benchmark 4 — harness §Roofline: reads the dry-run artifact JSONL and

prints the per-(arch x shape x mesh) roofline table (three terms, dominant
bottleneck, MODEL_FLOPS ratio).  Produced by:

  PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun.jsonl
"""

from __future__ import annotations

import json
import os

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "dryrun.jsonl")


def load(path=ARTIFACT):
    if not os.path.exists(path):
        return []
    recs = []
    with open(path) as f:
        for line in f:
            recs.append(json.loads(line))
    # newest record per (arch, shape, mesh) wins
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return list(dedup.values())


def print_table(recs):
    ok = [r for r in recs if r["status"] == "ok"]
    sk = [r for r in recs if r["status"] == "skipped"]
    print(f"\nRoofline table ({len(ok)} compiled, {len(sk)} skipped)")
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':8s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'useful%':>8s}")
    print(hdr)
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio") or 0
        print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:8s} "
              f"{t['compute_s']:10.3g} {t['memory_s']:10.3g} "
              f"{t['collective_s']:10.3g} {t['dominant']:>10s} "
              f"{100 * ratio:7.1f}%")
    for r in sk:
        print(f"{r['arch']:18s} {r['shape']:12s} {r.get('mesh', ''):8s} "
              f"SKIPPED: {r['reason']}")


def csv_rows(recs):
    rows = []
    for r in recs:
        if r["status"] != "ok":
            continue
        t = r["roofline"]
        step_us = max(t["compute_s"], t["memory_s"], t["collective_s"]) * 1e6
        rows.append((f"dryrun_{r['arch']}_{r['shape']}_{r['mesh']}", step_us,
                     f"dominant={t['dominant']};"
                     f"useful={100 * (r.get('useful_flops_ratio') or 0):.0f}%"))
    return rows


if __name__ == "__main__":
    print_table(load())
