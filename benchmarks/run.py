"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table2_run              Table II (model performance comparison)
  indep_*                 §IV.E population-independent analysis
  clustering              Fig. 2 pre-training clustering
  aggregation_*           §II.D server aggregation efficiency
  sharded_store_*         sharded-store submit throughput (-> BENCH_sharded.json)
  multiproc_store_*       threaded-K vs process-K serving mix (-> BENCH_multiproc.json)
  privatize_* / secure_*  privacy subsystem overhead (-> BENCH_privacy.json)
  scenario_*              trace-driven scenario replays (-> BENCH_scenarios.json)
  fed_round_*             Algorithm 1 protocol round timing
  dryrun_*                harness §Roofline rows (if artifacts exist)

Environment knobs: REPRO_BENCH_FAST=1 shrinks the Table-II run for CI.
"""

from __future__ import annotations

import os


def main() -> None:
    fast = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
    rows: list[tuple] = []

    # ---- Table II + §IV.E ---------------------------------------------------
    from benchmarks import table2

    t2_kwargs = (dict(seeds=(0,), n_sites=6, n_days=40, rounds=2) if fast
                 else dict(seeds=(0, 1, 2), n_sites=9, n_days=60, rounds=3))
    res = table2.run(**t2_kwargs)
    table2.print_table(res)
    rows += table2.csv_rows(res)
    for col, d in res["independent"].items():
        rows.append((f"indep_{col}", 0.0,
                     f"degradation={d['degradation_pp']:+.2f}pp"))

    # ---- clustering (Fig. 2) ------------------------------------------------
    from benchmarks import clustering_report

    crep = clustering_report.run()
    rows += clustering_report.csv_rows(crep)

    # ---- aggregation efficiency (§II.D) ------------------------------------
    from benchmarks import aggregation_throughput

    sizes = (200_000, 2_000_000) if fast else (200_000, 2_000_000, 20_000_000)
    arep = aggregation_throughput.run(sizes=sizes)
    rows += aggregation_throughput.csv_rows(arep)

    # ---- privacy overhead (DP + secure aggregation) -------------------------
    from benchmarks import privacy_overhead

    pret = privacy_overhead.run(fast=fast)
    rows += privacy_overhead.csv_rows(pret)

    # ---- sharded store submit throughput (-> BENCH_sharded.json) ------------
    from benchmarks import sharded_store

    srep = sharded_store.run(fast=fast)
    rows += sharded_store.csv_rows(srep)

    # ---- multi-process server serving mix (-> BENCH_multiproc.json) ---------
    from benchmarks import multiproc_store

    mrep = multiproc_store.run(fast=fast)
    rows += multiproc_store.csv_rows(mrep)

    # ---- trace-driven scenarios (-> BENCH_scenarios.json) -------------------
    from benchmarks import scenarios

    screp = scenarios.run(fast=fast)
    rows += scenarios.csv_rows(screp)

    # ---- protocol round timing (Algorithm 1) --------------------------------
    from benchmarks import protocol_timing

    prep = protocol_timing.run(fast=fast)
    rows += protocol_timing.csv_rows(prep)

    # ---- continual-learning ablation (§II.E) --------------------------------
    from benchmarks import continual_ablation

    crep2 = continual_ablation.run(epochs_a=4 if fast else 8,
                                   epochs_b=4 if fast else 8)
    rows += continual_ablation.csv_rows(crep2)

    # ---- roofline table (if dry-run artifacts exist) ------------------------
    from benchmarks import roofline_report

    recs = roofline_report.load()
    if recs:
        roofline_report.print_table(recs)
        rows += roofline_report.csv_rows(recs)

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
