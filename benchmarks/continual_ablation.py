"""Benchmark 6 — paper §II.E: catastrophic-forgetting mitigation ablation.

A client trains on task A (south-facing site), then continues on task B
(east-facing site, other region) with and without the L2-anchor/EWC
regularizer.  Reported: task-A error after B-training (anchored vs not)
and the parameter drift from the task-A anchor.

HONEST FINDING (see EXPERIMENTS.md §Repro note): on this synthetic fleet
cross-site training transfers *positively* (weather-forecast features
dominate, so task B improves the shared weather->power mapping) — the
paper's forgetting pathology does not manifest at this scale.  The EWC
*mechanism* is still validated: the anchored run's parameter drift is
roughly half the plain run's (plus closed-form/gradient unit tests in
tests/test_continual.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.solar_lstm import SolarLSTMConfig
from repro.core.continual import make_anchor
from repro.data.solar import SiteSpec, SolarDataGenerator
from repro.data.windows import batch_iter, make_windows, split_windows
from repro.models.lstm import SolarForecaster
from repro.training.fed_solar import make_solar_fns
from repro.training.metrics import summarize_errors


def run(seed: int = 0, hidden: int = 64, epochs_a: int = 8, epochs_b: int = 8,
        lam: float = 5.0):
    # conflicting tasks: A = south-facing Vienna site, B = east-facing site
    # in another region (different daily production shape — B-training
    # genuinely rotates the model away from A's behaviour)
    site_a = SiteSpec("abl-south", lat=48.2, lon=16.4, azimuth=180.0,
                      tilt=30.0, kwp=10.0, region=0)
    site_b = SiteSpec("abl-east", lat=50.1, lon=14.4, azimuth=95.0,
                      tilt=35.0, kwp=10.0, region=1)
    gen = SolarDataGenerator(n_days=45, seed=seed, start_day=100)
    wa = make_windows(gen.generate_site(site_a))
    wb = make_windows(gen.generate_site(site_b))
    tr_a, te_a = split_windows(wa, 0.8)
    tr_b, _ = split_windows(wb, 0.8)

    cfg = SolarLSTMConfig(hidden_size=hidden)
    fc = SolarForecaster(cfg)
    sgd_step, predict = make_solar_fns(fc, lr=1e-2)

    def train(params, windows, epochs, anchor_params, lam_):
        rng = np.random.default_rng(seed + 7)
        for _ in range(epochs):
            for b in batch_iter(windows, 8, rng):
                jb = {k: jnp.asarray(v) for k, v in b.items()
                      if k in ("history", "forecast", "target")}
                params, _ = sgd_step(params, jb, anchor_params,
                                     jnp.float32(lam_))
        return params

    def err_on(params, te):
        preds = np.asarray(predict(params, jnp.asarray(te["history"]),
                                   jnp.asarray(te["forecast"])))
        return summarize_errors(preds, te["target"], te["minute"])[
            "mean_error_power"]

    p0 = fc.init(jax.random.key(seed))
    p_a = train(p0, tr_a, epochs_a, None, 0.0)
    err_a_before = err_on(p_a, te_a)

    p_plain = train(p_a, tr_b, epochs_b, None, 0.0)
    p_ewc = train(p_a, tr_b, epochs_b, make_anchor(p_a).anchor, lam)

    def drift(p):
        return float(np.sqrt(sum(
            np.sum((np.asarray(x, np.float64) - np.asarray(y, np.float64)) ** 2)
            for x, y in zip(jax.tree.leaves(p), jax.tree.leaves(p_a), strict=True))))

    return {
        "task_a_error_after_a": err_a_before,
        "task_a_error_after_b_plain": err_on(p_plain, te_a),
        "task_a_error_after_b_ewc": err_on(p_ewc, te_a),
        "forgetting_plain_pp": err_on(p_plain, te_a) - err_a_before,
        "forgetting_ewc_pp": err_on(p_ewc, te_a) - err_a_before,
        "param_drift_plain": drift(p_plain),
        "param_drift_ewc": drift(p_ewc),
        "lam": lam,
    }


def csv_rows(rep):
    return [("continual_ewc", 0.0,
             f"forgetting_plain={rep['forgetting_plain_pp']:+.2f}pp;"
             f"forgetting_ewc={rep['forgetting_ewc_pp']:+.2f}pp;"
             f"drift_ratio={rep['param_drift_ewc'] / max(rep['param_drift_plain'], 1e-9):.2f}")]


if __name__ == "__main__":
    print(run())
