"""Privacy-subsystem overhead benchmark (DP path of the perf trajectory).

Three measurements, written to ``BENCH_privacy.json``:

  * ``privatize``   — DP clip+noise per update delta: Pallas kernel
    (interpret mode on CPU; the BlockSpec tiling is the TPU deliverable) vs
    the jitted jnp oracle, across model sizes;
  * ``secure_drain`` — plain coalesced drain vs the secure full-round drain
    (masked fused N-way sum incl. mask generation), same round shape;
  * ``secure_sim``   — end-to-end FedCCL sim rounds, plain vs secure+DP,
    with the achieved coalesce factor (the N-way drain amortization the
    masks ride on).
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    AggregationConfig,
    ModelMeta,
    UpdateDelta,
    coalesced_aggregate,
    secure_coalesced_aggregate,
)
from repro.kernels.dp_clip_noise.ops import privatize_flat
from repro.kernels.dp_clip_noise.ref import dp_clip_noise_ref
from repro.privacy.secure_agg import PairwiseMasker


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))            # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run_privatize(sizes=(200_000, 2_000_000)):
    rows = []
    rng = np.random.default_rng(0)
    for t in sizes:
        d = jnp.asarray(rng.standard_normal(t), jnp.float32)
        n = jnp.asarray(rng.standard_normal(t), jnp.float32)
        us_ref = _time(dp_clip_noise_ref, d, n, 1.0, 1.1)
        us_kernel = _time(lambda a, b: privatize_flat(a, b, 1.0, 1.1), d, n)
        # 3 passes over T f32 (read delta+noise, write out) + the norm read
        gbps = 4 * t * 4 / (us_ref / 1e6) / 1e9
        rows.append({"params": t, "jit_us": us_ref,
                     "pallas_interpret_us": us_kernel,
                     "jit_effective_GBps": gbps})
    return rows


def run_secure_drain(t=200_000, n_clients=8):
    """One full round folded plain vs masked (incl. client-side masking)."""
    rng = np.random.default_rng(1)
    masker = PairwiseMasker(seed=2, mask_scale=1.0)
    ids = [f"c{i}" for i in range(n_clients)]
    base = {"w": jnp.asarray(rng.standard_normal(t), jnp.float32)}
    meta = ModelMeta(1000, 3, 5)
    news, weights = [], []
    for _ in ids:
        news.append({"w": jnp.asarray(rng.standard_normal(t), jnp.float32)})
        weights.append(int(rng.integers(50, 500)))
    plain_updates = [(p, ModelMeta(s, 1, 6), UpdateDelta(s, 1, 1))
                     for p, s in zip(news, weights, strict=True)]
    cfg = AggregationConfig()

    def plain():
        return coalesced_aggregate(base, meta, plain_updates, cfg).params["w"]

    def secure():
        masked = [(masker.mask_update(base, p, cid, ids, 0, "__global__", s),
                   UpdateDelta(s, 1, 1))
                  for cid, p, s in zip(ids, news, weights, strict=True)]
        return secure_coalesced_aggregate(base, meta, masked, cfg).params["w"]

    return {"params": t, "round_clients": n_clients,
            "plain_drain_us": _time(plain), "secure_drain_us": _time(secure)}


def _scalar_train_fn(params, dataset, rng, anchor):
    target, n = dataset
    w = params["w"]
    for _ in range(3):
        g = w - target
        if anchor is not None:
            g = g + anchor.lam * (w - anchor.anchor["w"])
        w = w - 0.3 * g
    return {"w": w}, n, 3


def _make_fed(seed=0, **cfg_kw):
    """Two-group scalar federation (the protocol-timing fixture shape):
    heavy enough to exercise drains, light enough to time end-to-end."""
    from repro.core.fedccl import ClusterSpaceConfig, FedCCL, FedCCLConfig
    from repro.core.protocol import ClientSpec

    cfg = FedCCLConfig(
        spaces=(ClusterSpaceConfig("loc", eps=100.0, min_samples=2,
                                   metric="haversine"),),
        ewc_lambda=0.05, seed=seed, **cfg_kw)
    fed = FedCCL(cfg, {"w": jnp.zeros(())}, _scalar_train_fn)
    rng = np.random.default_rng(seed)
    specs = []
    for group, (lat, lon, tgt) in enumerate([(48.2, 16.4, +1.0),
                                             (52.5, 13.4, -1.0)]):
        for i in range(3):
            specs.append(ClientSpec(
                f"{'ab'[group]}{i}",
                {"loc": np.array([lat + rng.normal(0, .2),
                                  lon + rng.normal(0, .2)])},
                (tgt, 100), speed=rng.uniform(.5, 2)))
    fed.setup(specs)
    return fed


def run_secure_sim(rounds=3):
    """End-to-end sim: plain async vs secure+DP lockstep, coalesce factors."""
    out = {}
    t0 = time.perf_counter()
    fed = _make_fed(seed=0, batch_aggregation=True, max_coalesce=16)
    stats = fed.run(rounds=rounds)
    out["plain"] = {"wall_s": time.perf_counter() - t0,
                    "updates": stats["updates"],
                    "coalesce_factor": stats.get("coalesce_factor", 1.0)}
    t0 = time.perf_counter()
    fed = _make_fed(seed=0, secure_agg=True, dp_clip=1.0,
                    dp_noise_multiplier=0.5)
    stats = fed.run(rounds=rounds)
    out["secure_dp"] = {"wall_s": time.perf_counter() - t0,
                        "updates": stats["updates"],
                        "coalesce_factor": stats["coalesce_factor"],
                        "secure_rounds": stats["secure_rounds"]}
    eps = [r["epsilon"]
           for r in fed.privacy_report()["per_client"].values()]
    out["secure_dp"]["max_epsilon"] = max(eps)
    return out


def run(fast: bool = False, out_path: str = "BENCH_privacy.json") -> dict:
    sizes = (200_000,) if fast else (200_000, 2_000_000)
    report = {
        "privatize": run_privatize(sizes=sizes),
        "secure_drain": run_secure_drain(t=sizes[-1] // 10),
        "secure_sim": run_secure_sim(rounds=2 if fast else 3),
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def csv_rows(report: dict):
    rows = []
    for r in report["privatize"]:
        rows.append((f"privatize_{r['params']}", r["jit_us"],
                     f"GBps={r['jit_effective_GBps']:.1f};"
                     f"pallas_interpret_us={r['pallas_interpret_us']:.0f}"))
    sd = report["secure_drain"]
    rows.append((f"secure_drain_{sd['params']}", sd["secure_drain_us"],
                 f"plain_us={sd['plain_drain_us']:.0f};"
                 f"clients={sd['round_clients']}"))
    ss = report["secure_sim"]
    rows.append(("secure_sim_rounds", ss["secure_dp"]["wall_s"] * 1e6,
                 f"plain_wall_s={ss['plain']['wall_s']:.2f};"
                 f"coalesce_factor={ss['secure_dp']['coalesce_factor']:.2f};"
                 f"max_eps={ss['secure_dp']['max_epsilon']:.2f}"))
    return rows


if __name__ == "__main__":
    rep = run()
    for row in csv_rows(rep):
        print(row)
    print("report -> BENCH_privacy.json")
