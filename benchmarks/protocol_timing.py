"""Benchmark 5 — Algorithm 1 protocol round timing: how long one full

client round (local + cluster + global tiers) takes with the LSTM
forecaster, and the server-side aggregation share — the paper's "reduced
coordination overhead" claim measured on the simulated runtime.
"""

from __future__ import annotations

import time



def run(fast: bool = False):
    from repro.training.fed_solar import run_fedccl_solar

    n_sites, n_days, rounds = (4, 30, 1) if fast else (6, 40, 2)
    t0 = time.perf_counter()
    rep = run_fedccl_solar(n_sites=n_sites, n_days=n_days, rounds=rounds,
                           seed=0, n_independent=0)
    total_s = time.perf_counter() - t0
    updates = rep["async_stats"]["updates"]
    return {
        "total_s": total_s,
        "updates": updates,
        "us_per_update": total_s / max(updates, 1) * 1e6,
        "fast_path_frac": rep["async_stats"]["fast_path_frac"],
        "mean_staleness": rep["async_stats"]["mean_staleness"],
    }


def csv_rows(rep):
    return [("fed_round_update", rep["us_per_update"],
             f"fast_path={rep['fast_path_frac']:.2f};"
             f"staleness={rep['mean_staleness']:.2f}")]


if __name__ == "__main__":
    print(run(fast=True))
