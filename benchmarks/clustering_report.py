"""Benchmark 3 — paper Fig. 2 analog: pre-training clustering structure.

Reports the location / orientation clusters DBSCAN finds on the synthetic
fleet, cluster purity vs the generator's ground-truth regions, and
incremental-join behaviour (Predict phase latency in clustering terms).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.clustering import NOISE, IncrementalDBSCAN
from repro.data.solar import generate_fleet


def run(n_sites: int = 18, seed: int = 0):
    fleet = generate_fleet(n_sites=n_sites, n_days=2, seed=seed)
    sites = [s for s, _ in fleet]

    loc = IncrementalDBSCAN(eps=120.0, min_samples=2, metric="haversine")
    ori = IncrementalDBSCAN(eps=30.0, min_samples=2, metric="cyclic")
    t0 = time.perf_counter()
    for s in sites:
        loc.insert(np.array([s.lat, s.lon]))
        ori.insert(np.array([s.azimuth]))
    cluster_us = (time.perf_counter() - t0) / n_sites * 1e6

    # purity vs generator ground truth
    def purity(labels, truth):
        total = 0
        for c in set(labels) - {NOISE}:
            members = [truth[i] for i in range(len(labels)) if labels[i] == c]
            total += max(members.count(t) for t in set(members))
        n_clustered = int((labels != NOISE).sum())
        return total / max(n_clustered, 1)

    region_truth = [s.region for s in sites]
    az_truth = [int(s.azimuth // 60) for s in sites]
    report = {
        "n_sites": n_sites,
        "loc_clusters": loc.n_clusters,
        "ori_clusters": ori.n_clusters,
        "loc_noise": int((loc.labels == NOISE).sum()),
        "ori_noise": int((ori.labels == NOISE).sum()),
        "loc_purity": purity(loc.labels, region_truth),
        "ori_purity": purity(ori.labels, az_truth),
        "insert_us_per_site": cluster_us,
    }
    # Predict-phase join: new site near region 0
    t0 = time.perf_counter()
    label = loc.insert(np.array([48.25, 16.40]))
    report["join_us"] = (time.perf_counter() - t0) * 1e6
    report["join_label_valid"] = label != NOISE
    return report


def csv_rows(report):
    return [("clustering", report["insert_us_per_site"],
             f"loc_clusters={report['loc_clusters']};"
             f"loc_purity={report['loc_purity']:.2f};"
             f"ori_purity={report['ori_purity']:.2f}")]


if __name__ == "__main__":
    print(run())
