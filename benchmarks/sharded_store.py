"""Sharded ModelStore benchmark — multi-cluster submit throughput.

Scenario: W client threads each hammer the server with cluster + global
submits (the Algorithm-1 HandleModelUpdate hot path).  Compared stores:

  single_lock   ModelStore, batch_aggregation=False — every submit
                aggregates inline under the model lock; the global model's
                lock serializes *all* clients (the PR-0 baseline).
  flat_batched  ModelStore, batched — submits enqueue, one server drain
                thread coalesces (PR 1).
  sharded_K     ShardedModelStore at K shards — per-record/per-shard queue
                locks only on the submit path, K per-shard drain workers
                plus one two-level global drain worker (this PR).

Reported: wall-clock submits/s over the full stream (drains included for
the batched stores — workers run concurrently and are joined with a bounded
timeout before the clock stops), plus coalesce/partial accounting.  Writes
``BENCH_sharded.json``; run with ``REPRO_BENCH_FAST=1`` for the CI-sized
configuration.
"""

from __future__ import annotations

import json
import os
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import AggregationConfig, ModelMeta, UpdateDelta
from repro.core.runtime_threaded import AsyncThreadedRuntime
from repro.core.store import ModelStore, ShardedModelStore


def _make_pool(rng, t, n_trees):
    """Pre-built update payloads so the timed loop measures the store, not
    tree generation."""
    return [{"w": jnp.asarray(rng.standard_normal(t), jnp.float32)}
            for _ in range(n_trees)]


def _run_writers(store, pools, per_writer, n_clusters):
    keys = [f"c{i}" for i in range(n_clusters)]

    def writer(idx):
        pool = pools[idx]
        wrng = np.random.default_rng(10_000 + idx)
        for i in range(per_writer):
            tree = pool[i % len(pool)]
            s = int(wrng.integers(20, 200))
            key = keys[int(wrng.integers(n_clusters))]
            store.handle_model_update("cluster", key, tree,
                                      ModelMeta(s, 1, 1), UpdateDelta(s, 1, 1))
            store.handle_model_update("global", None, tree,
                                      ModelMeta(s, 1, 1), UpdateDelta(s, 1, 1))

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(len(pools))]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return t0


def _warm_store(store, tree, n_clusters):
    """Warm every jit/XLA cache the timed loop will hit, outside the clock:
    one pairwise fold, plus — for batched stores — each drain worker's
    power-of-two fold-arity buckets (``_pad_pow2`` keeps arities bucketed,
    so a handful of warm drains per shard covers every queue depth).  For a
    process-sharded store this warms each *worker's private* cache, which
    would otherwise pay its XLA compiles inside the measurement."""
    keys = [f"c{i}" for i in range(n_clusters)]
    store.handle_model_update("global", None, tree,
                              ModelMeta(10, 1, 1), UpdateDelta(10, 1, 1))
    if not store.batch_aggregation:
        return
    if hasattr(store, "shard_of"):
        reps = list({store.shard_of(k): k for k in keys}.values())
    else:
        reps = keys[:1]
    # n queued updates fold at arity n+1 (base included), padded to the next
    # power of two — a full max_coalesce batch lands in the next bucket up
    arities = [1]
    while arities[-1] * 2 <= store.max_coalesce:
        arities.append(arities[-1] * 2)
    for level, key in [("cluster", r) for r in reps] + [("global", None)]:
        for arity in arities:
            for _ in range(arity):
                store.handle_model_update(level, key, tree,
                                          ModelMeta(10, 1, 1),
                                          UpdateDelta(10, 1, 1))
            store.drain(level, key)
    store.drain_all()


def bench_store(name, store, *, n_writers, per_writer, n_clusters, t_params):
    rng = np.random.default_rng(0)
    pools = [_make_pool(np.random.default_rng(100 + i), t_params, 8)
             for i in range(n_writers)]
    warm = _make_pool(rng, t_params, 2)
    _warm_store(store, warm[0], n_clusters)
    n_warm = store.n_updates

    rt = None
    stop = threading.Event()
    if store.batch_aggregation:
        rt = AsyncThreadedRuntime([], store, drain_poll=1e-4,
                                  join_timeout=30.0)
        rt._start_drain_workers(stop)
    t0 = _run_writers(store, pools, per_writer, n_clusters)
    if rt is not None:
        rt._join_drain_workers(stop)      # drains flushed before clock stops
    wall = time.perf_counter() - t0

    submits = n_writers * per_writer * 2
    row = {
        "store": name,
        "shards": getattr(store, "n_shards", 0),
        "writers": n_writers,
        "clusters": n_clusters,
        "submits": submits,
        "wall_s": wall,
        "submits_per_s": submits / wall,
        "coalesce_factor": store.coalesce_factor(),
        "max_queue_depth": store.max_queue_depth,
    }
    stats = store.agg_stats()
    if "global_drains" in stats:
        row["global_drains"] = stats["global_drains"]
        row["global_partials"] = stats["global_partials"]
    assert store.n_updates - n_warm == submits, "lost updates in benchmark"
    return row


def run(fast: bool = False, out_path: str = "BENCH_sharded.json") -> dict:
    n_writers = 4 if fast else 8
    per_writer = 40 if fast else 150
    n_clusters = 16
    t_params = 20_000 if fast else 100_000
    rng = np.random.default_rng(0)
    init = {"w": jnp.asarray(rng.standard_normal(t_params), jnp.float32)}
    keys = [f"c{i}" for i in range(n_clusters)]
    cfg = AggregationConfig()
    kw = dict(n_writers=n_writers, per_writer=per_writer,
              n_clusters=n_clusters, t_params=t_params)

    rows = [
        bench_store("single_lock",
                    ModelStore(init, keys, agg_cfg=cfg), **kw),
        bench_store("flat_batched",
                    ModelStore(init, keys, agg_cfg=cfg,
                               batch_aggregation=True, max_coalesce=16), **kw),
    ]
    for k in (1, 4, 16):
        rows.append(bench_store(
            f"sharded_{k}",
            ShardedModelStore(init, keys, agg_cfg=cfg, n_shards=k,
                              batch_aggregation=True, max_coalesce=16), **kw))

    base = rows[0]["submits_per_s"]
    report = {
        "config": {"writers": n_writers, "per_writer": per_writer,
                   "clusters": n_clusters, "params": t_params},
        "rows": rows,
        "speedup_vs_single_lock": {
            r["store"]: r["submits_per_s"] / base for r in rows},
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def csv_rows(report: dict):
    out = []
    for r in report["rows"]:
        speedup = report["speedup_vs_single_lock"][r["store"]]
        out.append((f"sharded_store_{r['store']}",
                    r["wall_s"] * 1e6 / max(r["submits"], 1),
                    f"submits_per_s={r['submits_per_s']:.0f};"
                    f"speedup={speedup:.2f};"
                    f"coalesce={r['coalesce_factor']:.2f}"))
    return out


if __name__ == "__main__":
    rep = run(fast=os.environ.get("REPRO_BENCH_FAST", "0") == "1")
    for row in rep["rows"]:
        print(row)
    print("speedups vs single_lock:", {
        k: round(v, 2) for k, v in rep["speedup_vs_single_lock"].items()})
    print("report -> BENCH_sharded.json")
