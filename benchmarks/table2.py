"""Benchmark 1 — paper Table II: comprehensive model performance comparison.

Runs the full FedCCL solar experiment over multiple seeds and reports
mean +- std for every (model column, metric row), exactly Table II's shape.
The paper used 100 runs on the proprietary dataset; we default to a handful
of seeds on the synthetic fleet (see EXPERIMENTS.md §Repro for the
validated orderings).
"""

from __future__ import annotations

import time

import numpy as np

from repro.training.fed_solar import run_fedccl_solar

COLUMNS = ["CentralizedAll", "CentralizedContinual", "FederatedGlobal",
           "FederatedLocation", "FederatedOrientation", "FederatedLocal"]
METRICS = ["mean_error_power", "max_error_power", "mean_error_energy",
           "mean_error_day_power", "mean_error_day_energy"]


def run(seeds=(0, 1, 2), n_sites=9, n_days=60, rounds=3, **kw):
    t0 = time.time()
    runs = [run_fedccl_solar(n_sites=n_sites, n_days=n_days, rounds=rounds,
                             seed=s, **kw) for s in seeds]
    elapsed = time.time() - t0

    table = {}
    for col in COLUMNS:
        table[col] = {}
        for m in METRICS:
            vals = np.array([r["table2"][col][m] for r in runs])
            table[col][m] = (float(vals.mean()), float(vals.std()))

    indep = {}
    for col in ("FederatedGlobal", "FederatedLocation", "FederatedOrientation"):
        vals = np.array([r["independent"][col]["mean_error_power"]
                         for r in runs])
        tr = np.array([r["table2"][col]["mean_error_power"] for r in runs])
        indep[col] = {
            "indep_mean_error_power": (float(vals.mean()), float(vals.std())),
            "degradation_pp": float(vals.mean() - tr.mean()),
        }
    return {"table2": table, "independent": indep, "runs": len(seeds),
            "elapsed_s": elapsed, "async_stats": runs[0]["async_stats"]}


def print_table(result):
    table = result["table2"]
    print(f"\nTable II analog ({result['runs']} runs, synthetic fleet)")
    header = f"{'metric':26s}" + "".join(f"{c:>22s}" for c in COLUMNS)
    print(header)
    for m in METRICS:
        row = f"{m:26s}"
        for c in COLUMNS:
            mean, std = table[c][m]
            row += f"{mean:14.2f}±{std:5.2f}  "
        print(row)
    print("\nPopulation-independent (§IV.E):")
    for c, d in result["independent"].items():
        mean, std = d["indep_mean_error_power"]
        print(f"  {c:24s} indep power {mean:6.2f}±{std:4.2f}  "
              f"degradation {d['degradation_pp']:+.2f} pp")


def csv_rows(result):
    per_run_us = result["elapsed_s"] / result["runs"] * 1e6
    loc = result["table2"]["FederatedLocation"]["mean_error_power"][0]
    glob = result["table2"]["FederatedGlobal"]["mean_error_power"][0]
    cen = result["table2"]["CentralizedAll"]["mean_error_power"][0]
    deg = result["independent"]["FederatedLocation"]["degradation_pp"]
    return [
        ("table2_run", per_run_us,
         f"loc_power={loc:.2f}%;global_power={glob:.2f}%;"
         f"centralized_power={cen:.2f}%;indep_degradation={deg:+.2f}pp"),
    ]


if __name__ == "__main__":
    res = run()
    print_table(res)
