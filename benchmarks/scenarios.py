"""Scenario-engine benchmark — trace-driven federation replays as a
performance artifact (-> BENCH_scenarios.json).

Two phases, both pure ``repro.scenario`` runs (docs/SCENARIOS.md):

  diurnal_churn   the flagship 10^5-client day (diurnal availability +
                  churn + stragglers) replayed against the ``single``
                  and ``sharded`` topologies in one process, so the
                  machine cancels out of the gated ratio
                  (``sharded_vs_single_submits``).  The integrity SLOs
                  (zero lost updates, monotone effective_round) are
                  asserted inside the benchmark itself — an SLO break
                  fails the run, it is never just a slow number.  The
                  staleness tail (``staleness_p95``, in rounds) is
                  deterministic for a fixed trace + topology (seeded
                  RNG, synchronous drains), so the gate pins it as a
                  lower-is-better metric at the default tolerance.

  drift_ewc       the seasonal concept-drift scenario at lam=0 and
                  lam>0 with one seed: trajectories are bit-identical
                  up to the season boundary, so the EWC anchors are a
                  shared season-A reference and ``retention_ratio``
                  (baseline drift from the anchor over EWC drift, > 1
                  when the fused Pallas kernel is pulling its weight)
                  is a deterministic, gateable number.  ``kernel_calls``
                  rides along informationally — it proves the
                  ``ewc_update`` kernel is actually on the path.

``REPRO_BENCH_FAST=1`` (or ``fast=True``) shrinks the population for CI;
the shapes and assertions are identical.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.scenario import diurnal_churn, drift_ewc, run_scenario


def _integrity(rep):
    rep.assert_slo(lost_updates=0, effective_round_regressions=0,
                   drain_timeouts=0)
    return rep


def run(fast: bool = False, out_path: str = "BENCH_scenarios.json") -> dict:
    n, ticks = (20_000, 12) if fast else (100_000, 24)

    rows = []
    per_topology = {}
    for topology in ("single", "sharded"):
        rep = _integrity(run_scenario(diurnal_churn(n, ticks, seed=3),
                                      topology=topology, n_shards=4))
        row = rep.summary()
        rows.append(row)
        per_topology[topology] = row

    drift_n, drift_ticks = (2_000, 32) if fast else (5_000, 32)
    base = _integrity(run_scenario(
        drift_ewc(drift_n, drift_ticks, period=drift_ticks,
                  ewc_lambda=0.0, seed=13), topology="single"))
    ewc = _integrity(run_scenario(
        drift_ewc(drift_n, drift_ticks, period=drift_ticks,
                  ewc_lambda=25.0, seed=13), topology="single"))
    assert ewc.ewc["kernel_calls"] > 0, "EWC kernel never called"
    d_base = sum(float(np.linalg.norm(base.ewc["final_params"][k] - a))
                 for k, a in ewc.ewc["anchors"].items())
    d_ewc = sum(float(np.linalg.norm(ewc.ewc["final_params"][k] - a))
                for k, a in ewc.ewc["anchors"].items())

    report = {
        "config": {"n_clients": n, "n_ticks": ticks, "fast": bool(fast),
                   "drift_n_clients": drift_n, "drift_n_ticks": drift_ticks},
        "rows": rows,
        "sharded_vs_single_submits":
            per_topology["sharded"]["submits_per_s"]
            / per_topology["single"]["submits_per_s"],
        "staleness_p95": per_topology["sharded"]["slo_staleness_p95"],
        "drift": {
            "kernel_calls": ewc.ewc["kernel_calls"],
            "penalty_last": ewc.ewc["penalty_last"],
            "anchor_drift_baseline": d_base,
            "anchor_drift_ewc": d_ewc,
            "retention_ratio": d_base / max(d_ewc, 1e-9),
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def csv_rows(report: dict):
    rows = [(f"scenario_{r['name']}_{r['topology']}_submits_per_s",
             0.0, f"submits_per_s={r['submits_per_s']:.0f}")
            for r in report["rows"]]
    rows.append(("scenario_sharded_vs_single", 0.0,
                 f"ratio={report['sharded_vs_single_submits']:.2f}"))
    d = report["drift"]
    rows.append(("scenario_drift_retention", 0.0,
                 f"ratio={d['retention_ratio']:.2f},"
                 f"kernel_calls={d['kernel_calls']}"))
    return rows


if __name__ == "__main__":
    rep = run(fast=os.environ.get("REPRO_BENCH_FAST", "0") == "1")
    for r in rep["rows"]:
        print(f"{r['name']}/{r['topology']}: "
              f"{r['submits_per_s']:.0f} submits/s, "
              f"staleness p95 {r.get('slo_staleness_p95')}")
    print(f"sharded_vs_single: {rep['sharded_vs_single_submits']:.2f}")
    print(f"drift retention: {rep['drift']['retention_ratio']:.2f} "
          f"({rep['drift']['kernel_calls']} kernel calls)")
    print("report -> BENCH_scenarios.json")
