"""Benchmark 2 — server aggregation efficiency (paper §II.D efficiency

claims): Algorithm-2 weighted aggregation throughput, jit-tree path vs the
Pallas kernel path (interpret mode on CPU; the BlockSpec tiling is the TPU
deliverable), across model sizes from the case-study LSTM to LLM shards.

Also: the coalescing server path — N queued updates folded by one
``coalesced_aggregate`` call vs N sequential pairwise ``aggregate_models``
folds, plus a threaded-contention scenario showing coalesce factor > 1.
"""

from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import (
    AggregationConfig,
    ModelMeta,
    UpdateDelta,
    aggregate_models,
    coalesced_aggregate,
)
from repro.core.store import ModelStore
from repro.kernels.fedavg_agg.ops import aggregate_flat
from repro.kernels.fedavg_agg.ref import agg_ref


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))            # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(sizes=(200_000, 2_000_000, 20_000_000), n_models=2):
    rows = []
    rng = np.random.default_rng(0)
    ref_jit = jax.jit(agg_ref)
    for t in sizes:
        x = jnp.asarray(rng.standard_normal((n_models, t)), jnp.float32)
        w = jnp.asarray(rng.dirichlet(np.ones(n_models)), jnp.float32)
        us_ref = _time(ref_jit, x, w)
        us_kernel = _time(lambda a, b: aggregate_flat(a, b), x, w)
        gbps = (n_models + 1) * t * 4 / (us_ref / 1e6) / 1e9
        rows.append({
            "params": t,
            "jit_us": us_ref,
            "pallas_interpret_us": us_kernel,
            "jit_effective_GBps": gbps,
        })
    return rows


def _make_updates(rng, t, n, snapshot_round):
    """N stale updates that all fetched the same old snapshot — the
    queued-behind-one-lock shape.  With the base already past
    ``snapshot_round`` none of them hits the sequential fast path, so every
    one contributes through the weighted fold."""
    ups = []
    for _ in range(n):
        s = int(rng.integers(50, 500))
        ups.append(({"w": jnp.asarray(rng.standard_normal(t), jnp.float32)},
                    ModelMeta(s, 1, snapshot_round + 1), UpdateDelta(s, 1, 1)))
    return ups


def run_batched(sizes=(200_000, 2_000_000), batch_sizes=(4, 16)):
    """Batched (coalesced) drain vs sequential pairwise fold of the same
    queue: same result (parity-tested), 1 parameter pass instead of N-1."""
    rows = []
    rng = np.random.default_rng(1)
    cfg = AggregationConfig()
    for t in sizes:
        base = {"w": jnp.asarray(rng.standard_normal(t), jnp.float32)}
        meta = ModelMeta(1000, 3, 5)
        for n in batch_sizes:
            updates = _make_updates(rng, t, n, snapshot_round=1)

            def seq():
                p, m = base, meta
                for up, um, d in updates:
                    p, m = aggregate_models(p, m, up, um, d, cfg)
                return p

            def bat():
                return coalesced_aggregate(base, meta, updates, cfg).params

            us_seq = _time(lambda: seq()["w"])
            us_bat = _time(lambda: bat()["w"])
            rows.append({
                "params": t, "queued_updates": n,
                "sequential_us": us_seq, "batched_us": us_bat,
                "speedup": us_seq / us_bat,
            })
    return rows


def run_contention(n_writers=8, per_writer=20, t=100_000):
    """Threaded contention: writers enqueue non-blocking while one server
    drain thread sweeps — reports the achieved coalesce factor (>1 means
    multiple updates folded per parameter pass)."""
    rng = np.random.default_rng(2)
    store = ModelStore({"w": jnp.asarray(rng.standard_normal(t), jnp.float32)},
                       batch_aggregation=True, max_coalesce=32)

    def writer(i):
        wrng = np.random.default_rng(100 + i)
        for _ in range(per_writer):
            s = int(wrng.integers(50, 500))
            store.handle_model_update(
                "global", None,
                {"w": jnp.asarray(wrng.standard_normal(t), jnp.float32)},
                ModelMeta(s, 1, 0), UpdateDelta(s, 1, 1))

    stop = threading.Event()

    def drainer():
        while not stop.is_set():
            if store.drain_all() == 0:
                time.sleep(1e-4)
        store.drain_all()

    t0 = time.perf_counter()
    d = threading.Thread(target=drainer)
    ws = [threading.Thread(target=writer, args=(i,)) for i in range(n_writers)]
    d.start()
    for w in ws:
        w.start()
    for w in ws:
        w.join()
    stop.set()
    d.join()
    wall = time.perf_counter() - t0
    return {
        "updates": store.n_updates,
        "drain_batches": store.n_drain_batches,
        "coalesce_factor": store.coalesce_factor(),
        "max_queue_depth": store.max_queue_depth,
        "wall_s": wall,
        "updates_per_s": store.n_updates / wall,
    }


def csv_rows(rows):
    out = []
    for r in rows:
        out.append((f"aggregation_{r['params']}", r["jit_us"],
                    f"GBps={r['jit_effective_GBps']:.1f};"
                    f"pallas_interpret_us={r['pallas_interpret_us']:.0f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
    print("-- batched vs sequential fold --")
    for r in run_batched():
        print(r)
    print("-- threaded contention (coalescing drain) --")
    print(run_contention())
