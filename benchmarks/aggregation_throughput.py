"""Benchmark 2 — server aggregation efficiency (paper §II.D efficiency

claims): Algorithm-2 weighted aggregation throughput, jit-tree path vs the
Pallas kernel path (interpret mode on CPU; the BlockSpec tiling is the TPU
deliverable), across model sizes from the case-study LSTM to LLM shards.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fedavg_agg.ops import aggregate_flat
from repro.kernels.fedavg_agg.ref import agg_ref


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))            # warmup / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(sizes=(200_000, 2_000_000, 20_000_000), n_models=2):
    rows = []
    rng = np.random.default_rng(0)
    ref_jit = jax.jit(agg_ref)
    for t in sizes:
        x = jnp.asarray(rng.standard_normal((n_models, t)), jnp.float32)
        w = jnp.asarray(rng.dirichlet(np.ones(n_models)), jnp.float32)
        us_ref = _time(ref_jit, x, w)
        us_kernel = _time(lambda a, b: aggregate_flat(a, b), x, w)
        gbps = (n_models + 1) * t * 4 / (us_ref / 1e6) / 1e9
        rows.append({
            "params": t,
            "jit_us": us_ref,
            "pallas_interpret_us": us_kernel,
            "jit_effective_GBps": gbps,
        })
    return rows


def csv_rows(rows):
    out = []
    for r in rows:
        out.append((f"aggregation_{r['params']}", r["jit_us"],
                    f"GBps={r['jit_effective_GBps']:.1f};"
                    f"pallas_interpret_us={r['pallas_interpret_us']:.0f}"))
    return out


if __name__ == "__main__":
    for r in run():
        print(r)
