"""Multi-process federation server benchmark — threaded-K vs process-K
vs TCP-loopback, plus the lazy-mirror-sync bytes-on-wire comparison.

Scenario: the federation server's real serving mix.  W writer threads
hammer cluster + global submits (the Algorithm-1 HandleModelUpdate hot
path) while F fetcher threads serve ``RequestModel`` traffic — snapshot
read + msgpack wire serialization, the dominant request type in federated
serving (every client fetches each round; only some submit).  Drain
workers run concurrently and are joined with a bounded timeout before the
clock stops.  Compared at matched K:

  threaded_K   ShardedModelStore — K thread shards in one process.  Folds,
               fetch serialization, and submit bookkeeping all share one
               GIL, so aggregation and request serving are *additive*.
  process_K    ProcessShardedModelStore — K shard worker processes.
               Submits pay one msgpack serialization onto the shard's SPSC
               queue, cluster folds run in the workers, the global model
               merges via the cross-server partial merge — so aggregation
               *overlaps* request serving instead of stealing its GIL.
  tcp_K        the same store over ``server_hosts`` — K standalone shard
               servers (``repro.launch.shard_server``) on loopback TCP,
               the multi-host topology.  Rows carry the bytes-on-wire
               counters (``wire_tx_bytes``/``wire_rx_bytes``).

Mirror-sync phase (``mirror_sync``): one deterministic single-threaded
schedule replayed through two TCP stores — ``mirror_sync_every=1``
(eager) vs ``=4`` (lazy) — drained identically, mirrors synced, final
weights checksummed.  The lazy run must land on the SAME weights with a
fraction of the reply bytes; ``reply_bytes_ratio`` is the gated metric
(``scripts/bench_gate.py``) and ``weights_match`` is asserted here, so a
semantics regression fails the benchmark itself.

Fetch-storm phase (``fetch_storm``): the wire-v3 read tier at ~10x the
writer count on serving-size (~2 MB) snapshots — parent-served
(``request_model`` + per-fetch ``packb``, the pre-v3 path) vs
worker-served read sessions, unconditional and seq-conditional.  The
gated ratios are ``worker_vs_parent_fetches`` (conditional worker-served
throughput over parent-served) and ``conditional_bytes_ratio``
(conditional rx bytes over unconditional at the same fan-in).

Fold route: the accelerator aggregation path (``use_pallas=True`` —
``kernels/fedavg_agg``; Pallas interpret mode on CPU hosts), the
configuration the jax_pallas server targets.  One plain-jnp pair rides
along for the counter-regime: with near-free jitted folds there is nothing
to offload and the process store's transport makes it strictly slower —
kept in the artifact so the crossover is visible, not hidden.

Rebalance phase (``rebalance``): live-migration cost under load
(docs/ELASTICITY.md §6).  The mixed storm runs once as a pre-migration
window, then one forced ``migrate_cluster`` moves a cluster to another
worker while background submitters race the fence, then the same storm
runs again as the recovery window.  ``fence_pause_ms`` is the wall time
of the migrate call itself — the only interval the two workers' rpc
locks are held, i.e. the drain pause an operator sees — and
``recovery_ratio`` (gated, ``scripts/bench_gate.py``) is the
post-migration window's submits/s over the pre-migration window's:
1.0 means the hand-off left no lasting throughput scar.  Respawns
during the phase fail the benchmark itself — a migration that degrades
to journal-replay recovery is a bug, not a slow run.

Telemetry-overhead phase (``telemetry``): the same mixed storm on the
process store at the largest K, telemetry off vs on (every submit traced,
``trace_sample_n=1`` — the worst case).  ``telemetry_overhead`` is the
off/on submits/s ratio (1.0 = free) and is gated tight by
``scripts/bench_gate.py``: the observability layer's documented "≤ 5%
submit-throughput cost" claim (docs/OBSERVABILITY.md) is enforced, not
aspirational.

Reported per row: wall-clock submits/s over the full mixed workload
(drains included), fetches/s, coalesce accounting, and worker respawns
(must be 0 in a clean run).  The headline is ``process_vs_threaded`` — the
submit+drain throughput ratio at matched K on the kernel route.  Writes
``BENCH_multiproc.json``; ``REPRO_BENCH_FAST=1`` for the CI-sized config.
"""

from __future__ import annotations

import json
import os
import threading
import time

import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.sharded_store import _make_pool, _warm_store
except ImportError:                      # invoked as a script, not a module
    from sharded_store import _make_pool, _warm_store
from repro.checkpoint.msgpack_ckpt import packb
from repro.core.aggregation import AggregationConfig, ModelMeta, UpdateDelta
from repro.core.runtime_threaded import AsyncThreadedRuntime
from repro.core.store import ProcessShardedModelStore, ShardedModelStore
from repro.core.transport import LoopbackShardServers
from repro.obs.record import Telemetry

N_CLUSTERS = 16
MAX_COALESCE = 16


def bench_mixed(name, store, *, n_writers, per_writer, n_fetchers,
                per_fetcher, t_params):
    """One store under the mixed submit + fetch-serving storm."""
    keys = [f"c{i}" for i in range(N_CLUSTERS)]
    pools = [_make_pool(np.random.default_rng(100 + i), t_params, 8)
             for i in range(n_writers)]
    _warm_store(store, pools[0][0], N_CLUSTERS)
    n_warm = store.n_updates

    def writer(idx):
        pool = pools[idx]
        wrng = np.random.default_rng(10_000 + idx)
        for i in range(per_writer):
            tree = pool[i % len(pool)]
            s = int(wrng.integers(20, 200))
            key = keys[int(wrng.integers(N_CLUSTERS))]
            store.handle_model_update("cluster", key, tree,
                                      ModelMeta(s, 1, 1), UpdateDelta(s, 1, 1))
            store.handle_model_update("global", None, tree,
                                      ModelMeta(s, 1, 1), UpdateDelta(s, 1, 1))

    def fetcher(idx):
        frng = np.random.default_rng(20_000 + idx)
        for _ in range(per_fetcher):
            if frng.random() < 0.5:
                params, _ = store.request_model("global")
            else:
                params, _ = store.request_model(
                    "cluster", keys[int(frng.integers(N_CLUSTERS))])
            packb(params)        # wire-serialize the served snapshot

    rt = AsyncThreadedRuntime([], store, drain_poll=1e-4, join_timeout=180.0)
    stop = threading.Event()
    rt._start_drain_workers(stop)
    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_writers)] + \
              [threading.Thread(target=fetcher, args=(i,))
               for i in range(n_fetchers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rt._join_drain_workers(stop)          # drains flushed before clock stops
    wall = time.perf_counter() - t0

    submits = n_writers * per_writer * 2
    fetches = n_fetchers * per_fetcher
    row = {
        "store": name,
        "shards": getattr(store, "n_shards", 0),
        "writers": n_writers,
        "fetchers": n_fetchers,
        "submits": submits,
        "fetches": fetches,
        "wall_s": wall,
        "submits_per_s": submits / wall,
        "fetches_per_s": fetches / wall,
        "coalesce_factor": store.coalesce_factor(),
        "max_queue_depth": store.max_queue_depth,
    }
    stats = store.agg_stats()
    if "global_drains" in stats:
        row["global_drains"] = stats["global_drains"]
        row["global_partials"] = stats["global_partials"]
    if "respawns" in stats:
        row["respawns"] = stats["respawns"]
        row["drain_timeouts"] = stats["drain_timeouts"]
    if "wire_tx_bytes" in stats:                # bytes-on-wire (process/tcp)
        row["transport"] = stats["transport"]
        row["wire_tx_bytes"] = stats["wire_tx_bytes"]
        row["wire_rx_bytes"] = stats["wire_rx_bytes"]
    assert store.n_updates - n_warm == submits, "lost updates in benchmark"
    return row


def bench_mirror_sync(init, hosts, agg_cfg, n_updates):
    """Deterministic lazy-mirror-sync comparison: identical schedule,
    identical drain points, eager (sync_every=1) vs lazy (=4) TCP stores.
    Returns the phase report; asserts the final weights match."""
    keys = [f"c{i}" for i in range(N_CLUSTERS)]
    out = {}
    sums = {}
    for sync_every in (1, 4):
        rng = np.random.default_rng(7)
        pool = _make_pool(rng, 20_000, 8)
        store = ProcessShardedModelStore(
            init, keys, agg_cfg=agg_cfg, server_hosts=hosts,
            batch_aggregation=True, max_coalesce=MAX_COALESCE,
            mirror_sync_every=sync_every, drain_timeout_s=180.0)
        try:
            for i in range(n_updates):
                key = keys[i % N_CLUSTERS]
                s = int(rng.integers(20, 200))
                store.handle_model_update(
                    "cluster", key, pool[i % len(pool)],
                    ModelMeta(s, 1, 1), UpdateDelta(s, 1, 1))
                store.drain("cluster", key)     # one drain reply per update
            store.sync_mirrors()
            tx, rx = store.wire_bytes()
            sums[sync_every] = np.array(
                [float(np.asarray(store.params("cluster", k)["w"]).sum())
                 for k in keys])
            out[f"sync{sync_every}"] = {
                "mirror_sync_every": sync_every,
                "updates": n_updates,
                "wire_tx_bytes": tx,
                "reply_bytes": rx,
                "mirror_syncs": store.agg_stats()["mirror_syncs"],
            }
        finally:
            store.close()
    match = bool(np.allclose(sums[1], sums[4], atol=1e-4))
    assert match, "lazy mirror sync changed the final weights"
    out["weights_match"] = match
    out["reply_bytes_ratio"] = \
        out["sync4"]["reply_bytes"] / out["sync1"]["reply_bytes"]
    return out


def bench_fetch_storm(hosts, agg_cfg, *, n_fetchers, per_fetcher,
                      t_params=500_000, n_keys=8):
    """Read-tier storm (wire v3): the same fetch fan-in served three ways.

    ``parent``       every fetch is ``request_model`` + ``packb`` in the
                     parent process — the pre-v3 serving path, where the
                     parent pays one wire serialization per fetch.
    ``worker_full``  unconditional ``FetchClient`` fetches: the shard
                     servers' read sessions ship the cached packed
                     snapshot every time (no per-fetch ``packb``, but the
                     full payload crosses the wire and is decoded).
    ``worker_cond``  seq-conditional fetches — the read tier's steady
                     state: one full per (fetcher, key), not-modified
                     acks after.

    Sized for serving-size models (~2 MB snapshots at the default
    ``t_params``): that is the regime the read tier exists for — at toy
    sizes a loopback RPC costs more than the serialization it avoids.
    The fetcher count is ~10x the mixed storm's writers.  Gated ratios:
    ``worker_vs_parent_fetches`` (conditional worker-served fetches/s
    over parent-served, higher is better) and
    ``conditional_bytes_ratio`` (conditional rx bytes over unconditional
    rx bytes at the same fan-in, lower is better).
    """
    from repro.core.fetch import FetchClient

    rng = np.random.default_rng(11)
    init = {"w": jnp.asarray(rng.standard_normal(t_params), jnp.float32)}
    keys = [f"c{i}" for i in range(n_keys)]
    store = ProcessShardedModelStore(
        init, keys, agg_cfg=agg_cfg, server_hosts=hosts,
        batch_aggregation=True, max_coalesce=MAX_COALESCE,
        drain_timeout_s=180.0)
    try:
        for key in keys:                     # every worker holds a fold
            tree = {"w": jnp.asarray(rng.standard_normal(t_params),
                                     jnp.float32)}
            store.handle_model_update("cluster", key, tree,
                                      ModelMeta(50, 1, 1),
                                      UpdateDelta(50, 1, 1))
        store.drain_all()

        def storm(fn):
            res = [None] * n_fetchers
            threads = [threading.Thread(
                target=lambda i=i: res.__setitem__(i, fn(i)))
                for i in range(n_fetchers)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            return time.perf_counter() - t0, res

        def parent_served(idx):
            key = keys[idx % n_keys]
            for _ in range(per_fetcher):
                params, _ = store.request_model("cluster", key)
                packb(params)                # one serialization per fetch
            return {"rx": 0, "counts": {}}

        def worker_served(conditional):
            def fn(idx):
                with FetchClient(store, conditional=conditional) as fc:
                    key = keys[idx % n_keys]
                    for _ in range(per_fetcher):
                        fc.fetch("cluster", key)
                    assert fc.counts["fallback"] == 0, fc.counts
                    return {"rx": fc.rx_bytes, "counts": dict(fc.counts)}
            return fn

        out = {"fetchers": n_fetchers, "per_fetcher": per_fetcher,
               "params": t_params, "keys": n_keys}
        total = n_fetchers * per_fetcher
        for name, fn in (("parent", parent_served),
                         ("worker_full", worker_served(False)),
                         ("worker_cond", worker_served(True))):
            wall, res = storm(fn)
            out[name] = {
                "fetches_per_s": total / wall,
                "wall_s": wall,
                "rx_bytes": sum(r["rx"] for r in res),
                "not_modified": sum(r["counts"].get("not_modified", 0)
                                    for r in res),
            }
        assert store.agg_stats()["respawns"] == 0, "storm killed a worker"
        out["worker_vs_parent_fetches"] = \
            out["worker_cond"]["fetches_per_s"] / \
            out["parent"]["fetches_per_s"]
        out["conditional_bytes_ratio"] = \
            out["worker_cond"]["rx_bytes"] / out["worker_full"]["rx_bytes"]
        out["not_modified_frac"] = \
            out["worker_cond"]["not_modified"] / total
        return out
    finally:
        store.close()


def bench_rebalance(init, agg_cfg, k, kw):
    """Live-migration cost under the mixed storm: a pre-migration window,
    one forced ``migrate_cluster`` raced by background submitters, a
    recovery window.  Reports ``fence_pause_ms`` (the migrate call's wall
    time — the rpc-lock pause) and ``recovery_ratio`` (post/pre
    submits/s, gated)."""
    keys = [f"c{i}" for i in range(N_CLUSTERS)]
    store = ProcessShardedModelStore(init, keys, agg_cfg=agg_cfg, n_shards=k,
                                     batch_aggregation=True,
                                     max_coalesce=MAX_COALESCE,
                                     drain_timeout_s=180.0)
    try:
        pre = bench_mixed(f"rebalance_pre_{k}", store, **kw)

        mig_key = keys[0]
        src = store.shard_of(mig_key)
        dst = (src + 1) % k
        pool = _make_pool(np.random.default_rng(77), kw["t_params"], 4)

        def background(idx):
            # submits racing the fence: some land pre-flip on the old
            # owner (parked + redirected), some post-flip on the new one
            brng = np.random.default_rng(30_000 + idx)
            for i in range(200):
                if stop.is_set():
                    break
                s = int(brng.integers(20, 200))
                store.handle_model_update(
                    "cluster", keys[int(brng.integers(N_CLUSTERS))],
                    pool[i % len(pool)], ModelMeta(s, 1, 1),
                    UpdateDelta(s, 1, 1))

        stop = threading.Event()
        racers = [threading.Thread(target=background, args=(i,))
                  for i in range(2)]
        for t in racers:
            t.start()
        time.sleep(0.01)                 # let the racers reach the outbox
        t0 = time.perf_counter()
        epoch = store.migrate_cluster(mig_key, dst)
        fence_pause_ms = (time.perf_counter() - t0) * 1e3
        stop.set()
        for t in racers:
            t.join()
        store.drain_all()                # fold the raced submits

        post = bench_mixed(f"rebalance_post_{k}", store, **kw)
        stats = store.agg_stats()
        assert stats["cluster_migrations"] == 1, "exactly one forced move"
        assert stats["respawns"] == 0, \
            "migration degraded to journal-replay recovery"
        assert store.shard_of(mig_key) == dst, "fence did not hold"
        return {
            "shards": k,
            "migrated_key": mig_key,
            "src": src,
            "dst": dst,
            "epoch": epoch,
            "fence_pause_ms": fence_pause_ms,
            "pre_submits_per_s": pre["submits_per_s"],
            "post_submits_per_s": post["submits_per_s"],
            "pre_fetches_per_s": pre["fetches_per_s"],
            "post_fetches_per_s": post["fetches_per_s"],
            "recovery_ratio": post["submits_per_s"] / pre["submits_per_s"],
        }
    finally:
        store.close()


def bench_telemetry_overhead(init, agg_cfg, k, kw, reps=2):
    """The mixed storm on the process store, telemetry off vs on (every
    submit traced — the worst case); the off/on submits/s ratio is the
    gated ``telemetry_overhead`` metric (1.0 = free, gate at 1.05).

    The two modes alternate for ``reps`` repetitions and each mode keeps
    its *best* throughput: a single off-then-on pair conflates telemetry
    cost with process-spawn warm-up and scheduler luck (observed swings
    exceed 50% on shared runners), while best-of-alternating isolates
    the hook cost, which is what the 5% gate is about.
    """
    keys = [f"c{i}" for i in range(N_CLUSTERS)]
    best = {"off": 0.0, "on": 0.0}
    for rep in range(reps):
        for mode in ("off", "on"):
            store = ProcessShardedModelStore(
                init, keys, agg_cfg=agg_cfg, n_shards=k,
                batch_aggregation=True, max_coalesce=MAX_COALESCE,
                drain_timeout_s=180.0,
                telemetry=Telemetry() if mode == "on" else None)
            try:
                row = bench_mixed(f"process_tel_{mode}_{k}_r{rep}",
                                  store, **kw)
            finally:
                store.close()
            best[mode] = max(best[mode], row["submits_per_s"])
    return {
        "shards": k,
        "off_submits_per_s": best["off"],
        "on_submits_per_s": best["on"],
        "overhead_ratio": best["off"] / best["on"],
    }


def _bench_pair(tag, init, agg_cfg, k, kw):
    keys = [f"c{i}" for i in range(N_CLUSTERS)]
    threaded = bench_mixed(
        f"threaded_{tag}_{k}",
        ShardedModelStore(init, keys, agg_cfg=agg_cfg, n_shards=k,
                          batch_aggregation=True,
                          max_coalesce=MAX_COALESCE), **kw)
    store = ProcessShardedModelStore(init, keys, agg_cfg=agg_cfg, n_shards=k,
                                     batch_aggregation=True,
                                     max_coalesce=MAX_COALESCE,
                                     drain_timeout_s=180.0)
    try:
        proc = bench_mixed(f"process_{tag}_{k}", store, **kw)
    finally:
        store.close()
    return threaded, proc


def run(fast: bool = False, out_path: str = "BENCH_multiproc.json") -> dict:
    n_writers, n_fetchers = 4, 4
    per_writer = 60 if fast else 100
    per_fetcher = 3_000 if fast else 5_000
    t_params = 20_000
    ks = (1, 4) if fast else (1, 4, 8)
    rng = np.random.default_rng(0)
    init = {"w": jnp.asarray(rng.standard_normal(t_params), jnp.float32)}
    kw = dict(n_writers=n_writers, per_writer=per_writer,
              n_fetchers=n_fetchers, per_fetcher=per_fetcher,
              t_params=t_params)

    rows = []
    ratios = {}
    kernel_cfg = AggregationConfig(use_pallas=True)
    threaded_at_k = {}
    for k in ks:
        threaded, proc = _bench_pair("kernel", init, kernel_cfg, k, kw)
        rows += [threaded, proc]
        threaded_at_k[k] = threaded
        ratios[f"K{k}"] = proc["submits_per_s"] / threaded["submits_per_s"]
    # the nothing-to-offload counter-regime, one K for scale reference
    threaded, proc = _bench_pair("jnp", init, AggregationConfig(),
                                 max(ks), kw)
    rows += [threaded, proc]
    ratios[f"jnp_K{max(ks)}"] = \
        proc["submits_per_s"] / threaded["submits_per_s"]

    # multi-host topology: the same mixed storm over loopback TCP at the
    # largest K, plus the deterministic lazy-mirror-sync comparison —
    # both share one group of standalone shard servers
    k_tcp = max(ks)
    with LoopbackShardServers(k_tcp) as srv:
        store = ProcessShardedModelStore(
            init, [f"c{i}" for i in range(N_CLUSTERS)],
            agg_cfg=kernel_cfg, server_hosts=srv.hosts,
            batch_aggregation=True, max_coalesce=MAX_COALESCE,
            drain_timeout_s=180.0)
        try:
            tcp = bench_mixed(f"tcp_kernel_{k_tcp}", store, **kw)
        finally:
            store.close()
        rows.append(tcp)
        ratios[f"tcp_K{k_tcp}"] = \
            tcp["submits_per_s"] / threaded_at_k[k_tcp]["submits_per_s"]
        mirror_sync = bench_mirror_sync(init, srv.hosts, kernel_cfg,
                                        n_updates=48 if fast else 96)
        fetch_storm = bench_fetch_storm(
            srv.hosts, kernel_cfg, n_fetchers=10 * n_writers,
            per_fetcher=16 if fast else 60)

    telemetry = bench_telemetry_overhead(init, kernel_cfg, max(ks), kw)
    rebalance = bench_rebalance(init, kernel_cfg, max(ks), kw)

    report = {
        "config": {"writers": n_writers, "fetchers": n_fetchers,
                   "per_writer": per_writer, "per_fetcher": per_fetcher,
                   "clusters": N_CLUSTERS, "params": t_params,
                   "max_coalesce": MAX_COALESCE, "shard_counts": list(ks),
                   "tcp_shards": k_tcp, "fold_route": "kernel"},
        "rows": rows,
        "process_vs_threaded": ratios,
        "mirror_sync": mirror_sync,
        "fetch_storm": fetch_storm,
        "telemetry": telemetry,
        "rebalance": rebalance,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return report


def csv_rows(report: dict):
    out = []
    for r in report["rows"]:
        k = r["shards"]
        if r["store"].startswith("tcp_"):
            key = f"tcp_K{k}"
        elif "_kernel_" in r["store"]:
            key = f"K{k}"
        else:
            key = f"jnp_K{k}"
        ratio = report["process_vs_threaded"].get(key, 0.0)
        out.append((f"multiproc_store_{r['store']}",
                    r["wall_s"] * 1e6 / max(r["submits"], 1),
                    f"submits_per_s={r['submits_per_s']:.0f};"
                    f"fetches_per_s={r['fetches_per_s']:.0f};"
                    f"vs_thread_{key}={ratio:.2f}"))
    return out


if __name__ == "__main__":
    rep = run(fast=os.environ.get("REPRO_BENCH_FAST", "0") == "1")
    for row in rep["rows"]:
        print(row)
    print("vs threaded (submits/s ratio):", {
        k: round(v, 2) for k, v in rep["process_vs_threaded"].items()})
    ms = rep["mirror_sync"]
    print(f"lazy mirror sync: reply bytes x{ms['reply_bytes_ratio']:.2f} "
          f"({ms['sync4']['reply_bytes']} vs {ms['sync1']['reply_bytes']}), "
          f"weights_match={ms['weights_match']}")
    fs = rep["fetch_storm"]
    print(f"fetch storm ({fs['fetchers']} fetchers, {fs['params']} params): "
          f"parent {fs['parent']['fetches_per_s']:.0f}/s, worker-cond "
          f"{fs['worker_cond']['fetches_per_s']:.0f}/s "
          f"(x{fs['worker_vs_parent_fetches']:.2f}); conditional bytes "
          f"x{fs['conditional_bytes_ratio']:.3f} of unconditional")
    tl = rep["telemetry"]
    print(f"telemetry overhead (off/on submits/s at K{tl['shards']}): "
          f"x{tl['overhead_ratio']:.3f}")
    rb = rep["rebalance"]
    print(f"rebalance (K{rb['shards']}, {rb['migrated_key']} "
          f"{rb['src']}->{rb['dst']}): fence pause "
          f"{rb['fence_pause_ms']:.1f} ms, post-migration throughput "
          f"x{rb['recovery_ratio']:.2f} of pre")
    print("report -> BENCH_multiproc.json")
