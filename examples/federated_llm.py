"""FedCCL over assigned LLM architectures: demonstrates that the paper's

technique is model-agnostic — the same three-tier protocol federates a
dense, an MoE and an SSM architecture (reduced variants on CPU), with the
EWC continual-learning anchor active and the Pallas aggregation kernel on
the server path.

    PYTHONPATH=src python examples/federated_llm.py [--arch mamba2-370m]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_for_smoke
from repro.core.fedccl import ClusterSpaceConfig, FedCCL, FedCCLConfig
from repro.core.protocol import ClientSpec
from repro.data.lm_synth import lm_batch
from repro.models.model import build_model
from repro.optim.optimizers import adamw
from repro.training.train_step import TrainState, build_train_step


def federate(arch: str, n_orgs: int = 4, rounds: int = 2):
    cfg = reduced_for_smoke(get_config(arch))
    model = build_model(cfg)
    opt = adamw(2e-3)
    step = jax.jit(build_train_step(model, cfg, opt))
    eval_batch = lm_batch(np.random.default_rng(99), 4, 32, cfg.vocab_size)
    eval_jb = {k: jnp.asarray(v) for k, v in eval_batch.items()}

    from repro.training.train_step import build_eval_step

    eval_step = jax.jit(build_eval_step(model, cfg))

    def train_fn(params, dataset, rng, anchor):
        state = TrainState(params, opt.init(params))
        for _ in range(3):
            b = lm_batch(rng, 4, 32, cfg.vocab_size, structure=1.0)
            state, _ = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        return state.params, 12, 1

    init_params = model.init(jax.random.key(0))
    loss0 = float(eval_step(init_params, eval_jb)["loss"])

    fed = FedCCL(FedCCLConfig(
        spaces=(ClusterSpaceConfig("loc", eps=150.0, min_samples=2,
                                   metric="haversine"),),
        ewc_lambda=0.01, use_pallas_agg=True, seed=0),
        init_params, train_fn)

    rng = np.random.default_rng(0)
    centers = [(48.2, 16.4), (52.5, 13.4)]
    specs = [ClientSpec(f"org{i}",
                        {"loc": np.array(centers[i % 2])
                         + rng.normal(0, 0.1, 2)}, None)
             for i in range(n_orgs)]
    fed.setup(specs)
    stats = fed.run(rounds=rounds)
    loss1 = float(eval_step(fed.store.params("global"), eval_jb)["loss"])
    print(f"{arch:20s} eval loss {loss0:.3f} -> {loss1:.3f}  "
          f"updates={stats['updates']} "
          f"staleness={stats['mean_staleness']:.2f} "
          f"fast_path={stats['fast_path_frac']:.2f}")
    assert loss1 < loss0, "federated training should reduce eval loss"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="single arch id; default: one per family")
    args = ap.parse_args()
    archs = ([args.arch] if args.arch
             else ["gemma-2b", "deepseek-moe-16b", "mamba2-370m"])
    for arch in archs:
        federate(arch)


if __name__ == "__main__":
    main()
