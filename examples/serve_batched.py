"""Batched serving example: KV-cached decode across architecture families

(dense GQA cache, MoE + MLA latent cache, SSM constant state, hybrid
RG-LRU + rolling local window) — the serving-side counterpart of the
decode dry-runs.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config, reduced_for_smoke
from repro.models.model import build_model
from repro.serving.engine import ServeEngine

ARCHS = ["gemma-2b", "deepseek-v3-671b", "mamba2-370m", "recurrentgemma-9b"]


def main():
    rng = np.random.default_rng(0)
    for arch in ARCHS:
        cfg = reduced_for_smoke(get_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        engine = ServeEngine(model, params, max_len=96)
        prompts = rng.integers(0, cfg.vocab_size, (4, 12)).astype(np.int32)
        t0 = time.time()
        out = engine.generate(prompts, 48)
        dt = time.time() - t0
        print(f"{arch:20s} generated {out.shape[0]}x{out.shape[1]} tokens "
              f"in {dt:5.2f}s ({out.shape[0] * out.shape[1] / dt:6.1f} tok/s) "
              f"sample: {out[0, :8].tolist()}")

    # continuous batching: requests of different lengths share one decode
    # loop, each sequence at its own KV-cache offset (pos is a vector)
    cfg = reduced_for_smoke(get_config("gemma-2b"))
    model = build_model(cfg)
    engine = ServeEngine(model, model.init(jax.random.key(0)), max_len=96)
    reqs = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in (5, 11, 23)]
    out = engine.generate_ragged(reqs, 16)
    print(f"continuous-batching   3 ragged requests (len 5/11/23) -> "
          f"{out.shape[1]} new tokens each; sample: {out[:, :6].tolist()}")


if __name__ == "__main__":
    main()
