"""End-to-end driver (deliverable b): the paper's full case study.

Synthesizes a central-European PV fleet, clusters by location + panel
orientation, runs asynchronous FedCCL training, reports the Table-II
metric grid, evaluates Predict & Evolve on held-out installations, and
writes example prediction CSVs (Fig. 4/5 analogs) to artifacts/.

    PYTHONPATH=src python examples/solar_forecasting.py [--full]
"""

import argparse
import json
import os

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale-ish run (slower)")
    ap.add_argument("--out", default="artifacts")
    ap.add_argument("--dp-clip", type=float, default=None,
                    help="enable DP update privatization with this L2 clip")
    ap.add_argument("--dp-noise-multiplier", type=float, default=1.0,
                    help="Gaussian noise std = multiplier * clip")
    ap.add_argument("--secure-agg", action="store_true",
                    help="pairwise-mask secure aggregation (full-round drains)")
    args = ap.parse_args()

    from repro.training.fed_solar import run_fedccl_solar

    kw = (dict(n_sites=9, n_days=90, rounds=4, epochs=4) if args.full
          else dict(n_sites=6, n_days=40, rounds=2))
    report = run_fedccl_solar(seed=0, dp_clip=args.dp_clip,
                              dp_noise_multiplier=args.dp_noise_multiplier,
                              secure_agg=args.secure_agg, **kw)

    print("=== Table II analog ===")
    for name, row in report["table2"].items():
        print(f"{name:24s} power {row['mean_error_power']:6.2f}%  "
              f"energy {row['mean_error_energy']:6.2f}%  "
              f"day-power {row['mean_error_day_power']:6.2f}%")
    print("=== Population-independent (Predict & Evolve) ===")
    for name, row in report["independent"].items():
        deg = (row["mean_error_power"]
               - report["table2"][name]["mean_error_power"])
        print(f"{name:24s} power {row['mean_error_power']:6.2f}%  "
              f"(degradation {deg:+.2f} pp)")
    print("=== async protocol ===")
    print(json.dumps(report["async_stats"], indent=2))
    priv = report["privacy"]
    if priv["dp"]["enabled"] or priv["secure_agg"]["enabled"]:
        print("=== privacy ===")
        if priv["secure_agg"]["enabled"]:
            print(f"secure rounds {priv['secure_agg']['rounds']}  "
                  f"dropout recoveries {priv['secure_agg']['dropout_recoveries']}")
        for cid, row in sorted(priv.get("per_client", {}).items()):
            print(f"{cid:24s} eps={row['epsilon']:8.3f}  "
                  f"delta={row['delta']:.0e}  steps={row['steps']}")

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "solar_report.json"), "w") as f:
        json.dump(report, f, indent=2, default=str)
    print(f"full report -> {args.out}/solar_report.json")


if __name__ == "__main__":
    main()
