"""Quickstart: FedCCL in ~60 lines.

Three organizations in two geographic regions federate a (reduced) Gemma
model: pre-training DBSCAN clusters them, each trains locally, the server
aggregates per Algorithm 2 into cluster + global models, and a fourth org
joining later immediately receives its region's specialized model
(Predict & Evolve).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_for_smoke
from repro.core.fedccl import ClusterSpaceConfig, FedCCL, FedCCLConfig
from repro.core.protocol import ClientSpec
from repro.data.lm_synth import lm_batch
from repro.models.model import build_model
from repro.optim.optimizers import adamw
from repro.training.train_step import TrainState, build_train_step


def main():
    cfg = reduced_for_smoke(get_config("gemma-2b"))
    model = build_model(cfg)
    opt = adamw(1e-3)
    step = jax.jit(build_train_step(model, cfg, opt))

    def train_fn(params, dataset, rng, anchor):
        """Each org fine-tunes on its private token stream."""
        state = TrainState(params, opt.init(params))
        n_batches, bsz, seq = 4, 4, 32
        for _ in range(n_batches):
            batch = lm_batch(rng, bsz, seq, cfg.vocab_size)
            state, metrics = step(state, {k: jnp.asarray(v)
                                          for k, v in batch.items()})
        return state.params, n_batches * bsz, 1

    fed = FedCCL(
        FedCCLConfig(spaces=(ClusterSpaceConfig(
            "loc", eps=150.0, min_samples=2, metric="haversine"),),
            ewc_lambda=0.01, seed=0),
        init_params=model.init(jax.random.key(0)),
        train_fn=train_fn)

    orgs = [
        ClientSpec("org-vienna-1", {"loc": np.array([48.21, 16.37])}, None),
        ClientSpec("org-vienna-2", {"loc": np.array([48.30, 16.40])}, None),
        ClientSpec("org-berlin-1", {"loc": np.array([52.52, 13.40])}, None),
        ClientSpec("org-berlin-2", {"loc": np.array([52.45, 13.30])}, None),
    ]
    assignments = fed.setup(orgs)
    print("cluster assignments:", assignments)

    stats = fed.run(rounds=2)
    print("async stats:", stats)
    for key in fed.store.keys():
        meta = fed.store.meta("cluster", key)
        print(f"  cluster {key}: round={meta.round} "
              f"samples={meta.samples_learned}")

    # Predict & Evolve: a new Vienna org joins and gets the Vienna model
    keys, params = fed.join(
        ClientSpec("org-vienna-new", {"loc": np.array([48.25, 16.35])}, None))
    print(f"new org assigned to {keys}; received specialized params "
          f"({sum(x.size for x in jax.tree.leaves(params)):,} weights)")


if __name__ == "__main__":
    main()
