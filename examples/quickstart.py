"""Quickstart: FedCCL in ~80 lines, on any server topology.

Three organizations in two geographic regions federate a (reduced) Gemma
model: pre-training DBSCAN clusters them, each trains locally, the server
aggregates per Algorithm 2 into cluster + global models, and a fourth org
joining later immediately receives its region's specialized model
(Predict & Evolve).

``--topology`` selects the federation server flavor (one runnable
command per row of the README topology table; details in
docs/ARCHITECTURE.md):

    PYTHONPATH=src python examples/quickstart.py --topology single
    PYTHONPATH=src python examples/quickstart.py --topology sharded
    PYTHONPATH=src python examples/quickstart.py --topology process
    PYTHONPATH=src python examples/quickstart.py --topology tcp

``tcp`` spawns two standalone shard servers (``repro.launch.
shard_server``) on loopback ports via ``LoopbackShardServers`` — the
same entrypoint you run per host in a real multi-host deployment — and
points ``FedCCLConfig.server_hosts`` at them.

``--metrics`` enables the telemetry layer (``docs/OBSERVABILITY.md``) on
any topology and prints a one-screen latency/queue/staleness summary at
exit; ``--trace-out spans.json`` additionally writes a Perfetto-loadable
trace (open at ui.perfetto.dev) whose flow arrows follow each sampled
submit across the parent -> worker process/TCP boundary:

    PYTHONPATH=src python examples/quickstart.py --topology tcp \\
        --metrics --trace-out spans.json
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_for_smoke
from repro.core.fedccl import ClusterSpaceConfig, FedCCL, FedCCLConfig
from repro.core.protocol import ClientSpec
from repro.data.lm_synth import lm_batch
from repro.models.model import build_model
from repro.optim.optimizers import adamw
from repro.training.train_step import TrainState, build_train_step


def make_config(topology: str, hosts, telemetry: bool = False) -> FedCCLConfig:
    base = dict(spaces=(ClusterSpaceConfig(
        "loc", eps=150.0, min_samples=2, metric="haversine"),),
        ewc_lambda=0.01, seed=0, telemetry=telemetry)
    if topology == "single":
        return FedCCLConfig(**base)
    base["batch_aggregation"] = True
    if topology == "sharded":
        return FedCCLConfig(server_shards=2, **base)
    if topology == "process":
        return FedCCLConfig(server_processes=2, **base)
    if topology == "tcp":
        return FedCCLConfig(server_hosts=tuple(hosts),
                            mirror_sync_every=4, drain_timeout_s=120.0,
                            **base)
    raise ValueError(f"unknown topology {topology!r}")


def print_metrics_summary(fed: FedCCL) -> None:
    """One screen: merged cross-site percentiles for the run."""
    rep = fed.metrics_report()
    print(f"telemetry sites: {rep['sites']} "
          f"(dropped events: {rep['dropped_events']})")
    for name, h in sorted(rep["histograms"].items()):
        unit = " us" if name.endswith("_ns") else ""
        scale = 1e3 if name.endswith("_ns") else 1.0
        print(f"  {name:<22} n={h['count']:<6} "
              f"p50={h['p50'] / scale:>10.1f}{unit} "
              f"p95={h['p95'] / scale:>10.1f}{unit} "
              f"p99={h['p99'] / scale:>10.1f}{unit} "
              f"max={h['max'] / scale:>10.1f}{unit}")
    for name, v in sorted(rep["gauges"].items()):
        print(f"  {name:<22} {v}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--topology",
                    choices=("single", "sharded", "process", "tcp"),
                    default="single",
                    help="federation server flavor (see the README "
                         "topology table / docs/ARCHITECTURE.md)")
    ap.add_argument("--metrics", action="store_true",
                    help="enable telemetry and print a metrics summary at "
                         "exit (docs/OBSERVABILITY.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto-loadable trace-event JSON of the "
                         "run's span chains (implies --metrics)")
    args = ap.parse_args()
    telemetry = args.metrics or args.trace_out is not None

    cfg = reduced_for_smoke(get_config("gemma-2b"))
    model = build_model(cfg)
    opt = adamw(1e-3)
    step = jax.jit(build_train_step(model, cfg, opt))

    def train_fn(params, dataset, rng, anchor):
        """Each org fine-tunes on its private token stream."""
        state = TrainState(params, opt.init(params))
        n_batches, bsz, seq = 4, 4, 32
        for _ in range(n_batches):
            batch = lm_batch(rng, bsz, seq, cfg.vocab_size)
            state, metrics = step(state, {k: jnp.asarray(v)
                                          for k, v in batch.items()})
        return state.params, n_batches * bsz, 1

    servers = None
    if args.topology == "tcp":
        from repro.core.transport import LoopbackShardServers

        servers = LoopbackShardServers(2)
        print("loopback shard servers:", servers.hosts)
    try:
        fed = FedCCL(make_config(args.topology, servers.hosts if servers
                                 else (), telemetry),
                     init_params=model.init(jax.random.key(0)),
                     train_fn=train_fn)

        orgs = [
            ClientSpec("org-vienna-1", {"loc": np.array([48.21, 16.37])},
                       None),
            ClientSpec("org-vienna-2", {"loc": np.array([48.30, 16.40])},
                       None),
            ClientSpec("org-berlin-1", {"loc": np.array([52.52, 13.40])},
                       None),
            ClientSpec("org-berlin-2", {"loc": np.array([52.45, 13.30])},
                       None),
        ]
        assignments = fed.setup(orgs)
        print(f"topology {args.topology}: cluster assignments:", assignments)

        stats = fed.run(rounds=2)
        print("async stats:", stats)
        fed.store.sync_mirrors()       # no-op except under lazy mirror sync
        for key in fed.store.keys():
            meta = fed.store.meta("cluster", key)
            print(f"  cluster {key}: round={meta.round} "
                  f"samples={meta.samples_learned}")
        server_stats = fed.store.agg_stats()
        if "transport" in server_stats:
            print(f"  transport={server_stats['transport']} "
                  f"respawns={server_stats['respawns']} "
                  f"wire_rx_bytes={server_stats['wire_rx_bytes']}")

        # Predict & Evolve: a new Vienna org joins, gets the Vienna model
        keys, params = fed.join(
            ClientSpec("org-vienna-new", {"loc": np.array([48.25, 16.35])},
                       None))
        print(f"new org assigned to {keys}; received specialized params "
              f"({sum(x.size for x in jax.tree.leaves(params)):,} weights)")
        if telemetry:
            # dump before shutdown: the obsdump RPC needs live workers
            if args.trace_out:
                fed.write_trace(args.trace_out)
                print(f"wrote Perfetto trace to {args.trace_out} "
                      f"(open at ui.perfetto.dev)")
            print_metrics_summary(fed)
        fed.shutdown()
    finally:
        if servers is not None:
            servers.close()


if __name__ == "__main__":
    main()
